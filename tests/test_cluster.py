"""Cluster runtime: sharded sets, distributed shuffle, replica recovery.

The ISSUE-1 acceptance scenario: a 4-node cluster where every byte moves
through per-node unified buffer pools — shuffle, hash aggregation, and
kill-one-node recovery with checksum verification.
"""
import numpy as np
import pytest

from repro.core import shard_checksum
from repro.data.pipeline import (DistributedBatchLoader, cluster_aggregate,
                                 write_sharded_token_dataset)
from repro.runtime.cluster import (Cluster, ClusterShuffle, DeadNodeError,
                                   cluster_hash_aggregate, dispatch_plan)

PAIR = np.dtype([("key", np.int64), ("val", np.float64)])


def _pairs(n, key_range, seed=0):
    rng = np.random.default_rng(seed)
    recs = np.zeros(n, PAIR)
    recs["key"] = rng.integers(0, key_range, n)
    recs["val"] = rng.random(n)
    return recs


def _cluster(replication_factor=1, **kw):
    kw.setdefault("node_capacity", 16 << 20)
    kw.setdefault("page_size", 1 << 16)
    return Cluster(4, replication_factor=replication_factor, **kw)


# -- sharded locality sets ---------------------------------------------------
def test_sharded_set_partitions_by_key_hash():
    cluster = _cluster()
    recs = _pairs(20_000, 1000)
    sset = cluster.create_sharded_set("t", recs, key_fn=lambda r: r["key"])
    total = 0
    for n, info in sset.shards.items():
        shard = cluster.read_shard(sset, n)
        assert len(shard) == info.num_records
        total += len(shard)
        # placement follows the scheme: every record hashes to its node
        if len(shard):
            assert (sset.scheme.node_of_records(shard) == n).all()
        # same key -> same node, so key sets are disjoint across shards
    assert total == 20_000
    back = cluster.read_sharded(sset)
    assert np.array_equal(np.sort(back["key"]), np.sort(recs["key"]))


def test_sharded_set_replicas_live_on_other_nodes():
    cluster = _cluster(replication_factor=2)
    recs = _pairs(5_000, 100)
    sset = cluster.create_sharded_set("t", recs, key_fn=lambda r: r["key"])
    for n, info in sset.shards.items():
        holders = [h for h, _ in info.replicas]
        assert len(holders) == 2
        assert n not in holders           # never on the primary
        assert len(set(holders)) == 2     # distinct nodes
        for holder, rep_name in info.replicas:
            rep = cluster.nodes[holder].read_records(rep_name, sset.dtype)
            assert shard_checksum(rep) == info.checksum
    assert cluster.net_bytes >= recs.nbytes * 2  # replication crossed the wire


def test_checksums_recorded_per_shard():
    cluster = _cluster()
    recs = _pairs(8_000, 64)
    sset = cluster.create_sharded_set("t", recs, key_fn=lambda r: r["key"])
    for n in sset.shards:
        assert shard_checksum(cluster.read_shard(sset, n)) == \
            sset.shards[n].checksum


# -- distributed shuffle -----------------------------------------------------
def test_dispatch_plan_groups_contiguously():
    parts = np.array([2, 0, 1, 2, 0, 0, 3])
    order, counts, offsets = dispatch_plan(parts, 4)
    assert counts.tolist() == [3, 1, 2, 1]
    routed = parts[order]
    for p in range(4):
        assert (routed[offsets[p]:offsets[p + 1]] == p).all()


def test_cluster_shuffle_partitions_disjoint_and_complete():
    cluster = _cluster()
    recs = _pairs(30_000, 1 << 40, seed=3)
    sset = cluster.create_sharded_set("src", recs, key_fn=lambda r: r["key"])
    sh = ClusterShuffle(cluster, "sh", num_reducers=8, dtype=PAIR)
    sh.map_sharded(sset, key_fn=lambda r: r["key"])
    sh.finish_maps()
    pulled = [sh.pull(r) for r in range(8)]
    allk = np.concatenate([p["key"] for p in pulled])
    assert len(allk) == 30_000
    assert np.array_equal(np.sort(allk), np.sort(recs["key"]))
    for r, part in enumerate(pulled):
        assert (sh.partition_of_keys(part["key"]) == r).all()


def test_cluster_shuffle_counts_network_bytes():
    cluster = _cluster()
    recs = _pairs(10_000, 1 << 30, seed=4)
    sset = cluster.create_sharded_set("src", recs, key_fn=lambda r: r["key"])
    base_net = cluster.net_bytes
    sh = ClusterShuffle(cluster, "sh", num_reducers=4, dtype=PAIR)
    sh.map_sharded(sset, key_fn=lambda r: r["key"])
    sh.finish_maps()
    for r in range(4):
        sh.pull(r)
    # with 4 nodes and hash routing, ~3/4 of shuffle bytes cross nodes
    assert cluster.net_bytes - base_net > recs.nbytes / 2


def test_shuffle_map_output_released_after_pull():
    cluster = _cluster()
    recs = _pairs(5_000, 1000, seed=5)
    sset = cluster.create_sharded_set("src", recs, key_fn=lambda r: r["key"])
    sh = ClusterShuffle(cluster, "sh", num_reducers=4, dtype=PAIR)
    sh.map_sharded(sset, key_fn=lambda r: r["key"])
    sh.finish_maps()
    for r in range(4):
        sh.pull(r)
        sh.release_reducer(r)
    for node in cluster.nodes.values():
        for name in node.pool.paging.sets:
            assert "sh/map" not in name and "sh/reduce" not in name


# -- end-to-end hash aggregation --------------------------------------------
def test_cluster_hash_aggregation_matches_oracle():
    cluster = _cluster()
    recs = _pairs(50_000, 3_000, seed=6)
    sset = cluster.create_sharded_set("agg_src", recs,
                                      key_fn=lambda r: r["key"])
    keys, vals = cluster_hash_aggregate(cluster, sset, "key", "val",
                                        num_reducers=8)
    uk, inv = np.unique(recs["key"], return_inverse=True)
    oracle = np.zeros(len(uk))
    np.add.at(oracle, inv, recs["val"])
    assert np.array_equal(keys, uk)
    np.testing.assert_allclose(vals, oracle, rtol=1e-9)


def test_pipeline_cluster_aggregate_cleans_up():
    cluster = _cluster()
    recs = _pairs(20_000, 500, seed=7)
    keys, vals = cluster_aggregate(cluster, "sales", recs, "key", "val")
    assert len(keys) == len(np.unique(recs["key"]))
    assert "sales" not in cluster.catalog
    for node in cluster.nodes.values():  # staged data dropped after the job
        assert not any(n.startswith("sales/") for n in node.pool.paging.sets)


# -- replica-based recovery --------------------------------------------------
def test_dead_node_access_raises_without_replicas():
    """With no replicas, a dead owner really is unreadable."""
    cluster = _cluster(replication_factor=0)
    recs = _pairs(4_000, 100, seed=8)
    sset = cluster.create_sharded_set("t", recs, key_fn=lambda r: r["key"])
    cluster.kill_node(1)
    with pytest.raises(DeadNodeError):
        cluster.read_shard(sset, 1)
    with pytest.raises(DeadNodeError):
        cluster.read_sharded(sset)


def test_dead_node_reads_fall_back_to_replica():
    """The PR-1 bug: a dead node with surviving replicas still killed reads.
    Reads now route to a CRC-verified replica holder."""
    cluster = _cluster()
    recs = _pairs(4_000, 100, seed=8)
    sset = cluster.create_sharded_set("t", recs, key_fn=lambda r: r["key"])
    lost = np.sort(cluster.read_shard(sset, 1)["key"]).copy()
    cluster.kill_node(1)
    holder, shard = cluster.read_shard_from(sset, 1)
    assert holder != 1 and cluster.nodes[holder].alive
    assert np.array_equal(np.sort(shard["key"]), lost)
    back = cluster.read_sharded(sset)  # whole-set read survives the loss
    assert np.array_equal(np.sort(back["key"]), np.sort(recs["key"]))


@pytest.mark.parametrize("victim", [0, 1, 2, 3])
def test_kill_one_node_recovery_any_victim(victim):
    cluster = _cluster()
    recs = _pairs(25_000, 2_000, seed=victim)
    sset = cluster.create_sharded_set("t", recs, key_fn=lambda r: r["key"])
    lost = np.sort(cluster.read_shard(sset, victim)["key"]).copy()
    cluster.kill_node(victim)
    report = cluster.recover_node(victim)
    assert report.ok
    assert report.shards_recovered == 1
    assert report.bytes_transferred > 0
    rebuilt = cluster.read_shard(sset, victim)
    assert np.array_equal(np.sort(rebuilt["key"]), lost)
    assert shard_checksum(rebuilt) == sset.shards[victim].checksum
    back = cluster.read_sharded(sset)
    assert np.array_equal(np.sort(back["key"]), np.sort(recs["key"]))


def test_recovery_restores_replication_factor():
    cluster = _cluster(replication_factor=2)
    recs = _pairs(10_000, 300, seed=11)
    sset = cluster.create_sharded_set("t", recs, key_fn=lambda r: r["key"])
    cluster.kill_node(3)
    report = cluster.recover_node(3)
    assert report.ok
    # node 3 held replicas for its two predecessors; both must be back
    assert report.replicas_rebuilt == 2
    for owner, info in sset.shards.items():
        for holder, rep_name in info.replicas:
            rep = cluster.nodes[holder].read_records(rep_name, sset.dtype)
            assert shard_checksum(rep) == info.checksum


def test_recovery_spans_multiple_sharded_sets():
    cluster = _cluster()
    a = cluster.create_sharded_set("a", _pairs(6_000, 64, seed=12),
                                   key_fn=lambda r: r["key"])
    b = cluster.create_sharded_set("b", _pairs(9_000, 128, seed=13),
                                   key_fn=lambda r: r["key"])
    cluster.kill_node(0)
    report = cluster.recover_node(0)
    assert report.ok and report.shards_recovered == 2
    for sset in (a, b):
        assert shard_checksum(cluster.read_shard(sset, 0)) == \
            sset.shards[0].checksum


def test_aggregation_still_correct_after_recovery():
    cluster = _cluster()
    recs = _pairs(30_000, 1_500, seed=14)
    sset = cluster.create_sharded_set("t", recs, key_fn=lambda r: r["key"])
    cluster.kill_node(2)
    assert cluster.recover_node(2).ok
    keys, vals = cluster_hash_aggregate(cluster, sset, "key", "val")
    uk, inv = np.unique(recs["key"], return_inverse=True)
    oracle = np.zeros(len(uk))
    np.add.at(oracle, inv, recs["val"])
    assert np.array_equal(keys, uk)
    np.testing.assert_allclose(vals, oracle, rtol=1e-9)


# -- distributed token dataset ----------------------------------------------
def test_sharded_token_dataset_roundtrip():
    cluster = _cluster()
    rng = np.random.default_rng(15)
    toks = rng.integers(0, 1000, (512, 32), dtype=np.int32)
    sset = write_sharded_token_dataset(cluster, "tok", toks)
    loader = DistributedBatchLoader(cluster, sset, batch_size=64)
    batches = list(loader)
    assert len(batches) == 8
    seen = np.concatenate([b["tokens"] for b in batches])
    assert np.array_equal(np.sort(seen[:, 0]), np.sort(toks[:, 0]))
    for b in batches:
        assert b["labels"].shape == b["tokens"].shape
        assert (b["labels"][:, -1] == -100).all()
        assert np.array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


# -- conflicting-object guards (paper §7, carried bugfix) --------------------
def test_factor0_conflicting_pair_recovers_via_guard():
    """Regression: a factor-0 heterogeneous pair where the same node holds
    shards under BOTH partitionings. The records both schemes route there
    die with the node, and without the registration-time guard copy neither
    set can rebuild the other — recovery used to report failure."""
    cluster = _cluster(replication_factor=0)
    recs = _pairs(12_000, 800, seed=31)
    base = cluster.create_sharded_set("ev", recs, key_fn=lambda r: r["key"],
                                      partition_key="key")
    alt = cluster.create_sharded_set(
        "ev_by_val", recs, partition_key="val",
        key_fn=lambda r: (r["val"] * 1e6).astype(np.int64))
    cluster.register_replica_set("ev", alt)
    guards = cluster.conflict_guards[("ev", "ev_by_val")]
    assert guards, "no conflicted node — setup lost its point"
    victim = sorted(guards)[0]
    g = guards[victim]
    assert g.holder != victim              # the guard survives the kill
    order = ["key", "val"]
    expect_base = np.sort(cluster.read_sharded(base), order=order)
    expect_alt = np.sort(cluster.read_sharded(alt), order=order)
    cluster.kill_node(victim)
    report = cluster.recover_node(victim)
    assert report.ok, report.checksum_failures
    assert report.sources[f"ev:{victim}"] == "rebuild<-ev_by_val"
    assert report.sources[f"ev_by_val:{victim}"] == "rebuild<-ev"
    assert np.array_equal(np.sort(cluster.read_sharded(base), order=order),
                          expect_base)
    assert np.array_equal(np.sort(cluster.read_sharded(alt), order=order),
                          expect_alt)
    cluster.shutdown()


def test_no_guards_written_when_either_side_carries_replicas():
    """Chain replicas already cover the conflict: guards are a factor-0-pair
    mechanism only, so a replicated pair must not pay the extra copies."""
    cluster = _cluster(replication_factor=1)
    recs = _pairs(8_000, 500, seed=32)
    cluster.create_sharded_set("a", recs, key_fn=lambda r: r["key"],
                               partition_key="key")
    alt = cluster.create_sharded_set(
        "a_by_val", recs, partition_key="val",
        key_fn=lambda r: (r["val"] * 1e6).astype(np.int64))
    cluster.register_replica_set("a", alt)
    assert cluster.conflict_guards.get(("a", "a_by_val"), {}) == {}
    cluster.shutdown()


def test_dropping_a_set_drops_its_guards():
    cluster = _cluster(replication_factor=0)
    recs = _pairs(10_000, 600, seed=33)
    cluster.create_sharded_set("d", recs, key_fn=lambda r: r["key"],
                               partition_key="key")
    alt = cluster.create_sharded_set(
        "d_by_val", recs, partition_key="val",
        key_fn=lambda r: (r["val"] * 1e6).astype(np.int64))
    cluster.register_replica_set("d", alt)
    guards = dict(cluster.conflict_guards[("d", "d_by_val")])
    assert guards
    cluster.drop_sharded_set(alt)
    assert ("d", "d_by_val") not in cluster.conflict_guards
    for g in guards.values():              # the guard copies were freed
        assert not cluster.scheduler._holds(g.holder, g.set_name)
    cluster.shutdown()
