"""Columnar page layout (PR 7): block-format roundtrip, checksum
byte-compatibility with the row scheme, per-field CRC chain invariance,
the fused dispatch-plan kernel vs its host fallback (the PR-7 resolution
bugfix), the zero-intermediate gather landing, cluster-level byte identity
(including the over-capacity spill path and pull verification flags), the
shuffle -> aggregate -> join property sweep, and the pagelog fsync policy
knob."""
import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import BufferPool
from repro.core.columnar import (ColumnarWriter, ColumnLayout,
                                 columnar_content_checksum, columns_crc32,
                                 columns_to_records, fused_partition_crc,
                                 iter_column_blocks, records_to_columns,
                                 route_partition_ids)
from repro.core.pagelog import FSYNC_POLICIES, PageLog, fsck
from repro.core.replication import record_content_checksum
from repro.core.services import canonical_join_sort, columnar_job_data_attrs
from repro.runtime.cluster import (Cluster, ClusterShuffle,
                                   _host_dispatch_plan,
                                   cluster_hash_aggregate, dispatch_impl,
                                   dispatch_plan)
from repro.runtime.join import cluster_join

REC = np.dtype([("key", np.int64), ("payload", np.uint8, (10,))])
PAIR = np.dtype([("key", np.int64), ("val", np.float64)])


def _recs(n, seed=0):
    rng = np.random.default_rng(seed)
    out = np.zeros(n, REC)
    out["key"] = rng.integers(-(1 << 40), 1 << 40, n)
    out["payload"] = rng.integers(0, 256, (n, 10))
    return out


def _bytesorted(recs):
    """Canonical order for REC (its multi-dim payload defeats lexsort over
    fields): plain byte-lexicographic sort of the packed records."""
    if len(recs) <= 1:
        return recs
    a = np.frombuffer(recs.tobytes(), np.uint8).reshape(len(recs),
                                                        recs.itemsize)
    order = np.lexsort(tuple(a[:, i] for i in reversed(range(recs.itemsize))))
    return recs[order]


def _pairs(n, key_range, seed=0):
    rng = np.random.default_rng(seed)
    out = np.zeros(n, PAIR)
    out["key"] = rng.integers(0, key_range, n)
    # integer-valued floats: sums are exact regardless of reduction order,
    # so row and columnar aggregates must agree bit-for-bit
    out["val"] = rng.integers(0, 1000, n).astype(np.float64)
    return out


# -- block format -------------------------------------------------------------
def test_block_roundtrip_across_page_splits():
    pool = BufferPool(4 << 20)
    ls = pool.create_set("c", 1 << 12, columnar_job_data_attrs())
    recs = _recs(2000)
    assert ColumnLayout.for_page(REC, 1 << 12).capacity < 2000  # splits
    w = ColumnarWriter(pool, ls, REC)
    w.append_batch(recs)
    w.close()
    got = np.concatenate([columns_to_records(cols, REC, n)
                          for cols, n in iter_column_blocks(pool, ls, REC)])
    assert np.array_equal(got, recs)


def test_content_checksum_matches_row_scheme():
    recs = _recs(1234)
    cols = records_to_columns(recs)
    assert columnar_content_checksum(cols, REC) == \
        record_content_checksum(recs)
    assert columnar_content_checksum(records_to_columns(np.zeros(0, REC)),
                                     REC, 0) == 0


def test_per_field_crc_chains_invariant_to_splits():
    recs = _recs(777)
    cols = records_to_columns(recs)
    whole = columns_crc32(cols, REC, 0, len(recs))
    for splits in ([0, 777], [0, 1, 777], [0, 100, 101, 400, 777]):
        crcs = None
        for lo, hi in zip(splits, splits[1:]):
            crcs = columns_crc32(cols, REC, lo, hi, crcs)
        assert crcs == whole


# -- fused dispatch plan (PR-7 resolution bugfix) -----------------------------
def test_dispatch_plan_resolves_kernel_path_once():
    """The ImportError used to be swallowed per call, silently pinning the
    host fallback; the resolution is now cached and observable. This
    container ships jax, so the kernel package must win."""
    assert dispatch_impl() == "kernels.shuffle_dispatch"
    assert dispatch_impl() == "kernels.shuffle_dispatch"  # cached


@pytest.mark.parametrize("case", ["random", "empty", "single_partition",
                                  "all_same_key"])
def test_kernel_plan_matches_host_plan(case):
    rng = np.random.default_rng(7)
    parts = {
        "random": rng.integers(0, 16, 5000).astype(np.uint8),
        "empty": np.zeros(0, np.uint8),
        "single_partition": np.full(4096, 3, np.uint8),
        "all_same_key": np.zeros(100, np.uint8),
    }[case]
    from repro.kernels.shuffle_dispatch.ops import host_dispatch_plan
    got = host_dispatch_plan(parts, 16)
    want = _host_dispatch_plan(parts, 16)
    for g, w in zip(got, want):
        assert np.array_equal(g, w)
    order, counts, offsets = got
    assert counts.sum() == len(parts) and offsets[-1] == len(parts)
    # the plan really groups: every slice holds exactly its partition's rows
    for p in range(16):
        sl = order[offsets[p]:offsets[p + 1]]
        assert np.all(parts[sl] == p)


def test_gather_landing_matches_fused_reference():
    """The zero-intermediate landing (np.take straight into page regions +
    CRC over landed bytes) must byte-match the reference fused pass that
    materializes a routed intermediate."""
    recs = _recs(3000, seed=5)
    cols = records_to_columns(recs)
    keys = cols["key"]
    P = 4
    routed, counts, offsets, want_crcs = fused_partition_crc(
        keys, cols, REC, P)
    h = route_partition_ids(keys, P)
    order, counts2, offsets2 = dispatch_plan(h.astype(np.uint8), P)
    assert np.array_equal(counts, counts2)
    assert np.array_equal(offsets, offsets2)
    pool = BufferPool(8 << 20)
    bounds = offsets.tolist()
    for p in range(P):
        ls = pool.create_set(f"part{p}", 1 << 13, columnar_job_data_attrs())
        w = ColumnarWriter(pool, ls, REC)
        got = w.gather_append(cols, order, bounds[p], bounds[p + 1])
        w.close()
        assert got == want_crcs[p]
        lo, hi = bounds[p], bounds[p + 1]
        want = columns_to_records(
            {name: routed[name][lo:hi] for name in routed}, REC, hi - lo)
        landed = [columns_to_records(c, REC, n)
                  for c, n in iter_column_blocks(pool, ls, REC)]
        assert np.array_equal(np.concatenate(landed), want)


# -- cluster shuffle byte identity --------------------------------------------
def _shuffle_partitions(columnar, n=6000, node_capacity=32 << 20,
                        page_size=1 << 16):
    cluster = Cluster(4, node_capacity=node_capacity, page_size=page_size,
                      replication_factor=0)
    rng = np.random.default_rng(3)
    recs = np.zeros(n, REC)
    recs["key"] = rng.zipf(1.3, n).astype(np.int64)
    recs["payload"] = rng.integers(0, 256, (n, 10))
    sset = cluster.create_sharded_set(
        "s", recs, key_fn=lambda r: r["key"],
        attrs_factory=columnar_job_data_attrs if columnar else None)
    sh = ClusterShuffle(cluster, "sh", num_reducers=4, dtype=REC,
                        columnar=columnar)
    for s in sorted(sset.shards):
        sh.map_shard(sset, s, key_fn=lambda r: r["key"], key_field="key")
    sh.finish_maps()
    parts = []
    for r in range(4):
        parts.append(_bytesorted(sh.pull(r)))
        sh.release_reducer(r)
    spill = sum(node.memory.stats["spill_bytes"]
                for node in cluster.nodes.values())
    cluster.shutdown()
    return parts, spill


def test_columnar_shuffle_byte_identical_to_row():
    row, _ = _shuffle_partitions(columnar=False)
    col, _ = _shuffle_partitions(columnar=True)
    for r in range(4):
        assert np.array_equal(row[r], col[r])
        assert record_content_checksum(row[r]) == \
            record_content_checksum(col[r])


def test_columnar_shuffle_byte_identical_under_spill():
    """Over-capacity: map output + staging exceed the per-node pools, so
    landing pages spill and fault back during the pull — the bytes must
    still verify."""
    n = 40000
    cap = 192 << 10
    row, srow = _shuffle_partitions(columnar=False, n=n, node_capacity=cap,
                                    page_size=1 << 13)
    col, scol = _shuffle_partitions(columnar=True, n=n, node_capacity=cap,
                                    page_size=1 << 13)
    assert scol > 0, "columnar run never spilled — not over capacity"
    for r in range(4):
        assert np.array_equal(row[r], col[r])


def test_pull_columns_flags_and_deferred_release():
    cluster = Cluster(4, node_capacity=32 << 20, page_size=1 << 16,
                      replication_factor=0)
    recs = _recs(4000, seed=11)
    sset = cluster.create_sharded_set(
        "s", recs, key_fn=lambda r: r["key"],
        attrs_factory=columnar_job_data_attrs)
    sh = ClusterShuffle(cluster, "sh", num_reducers=4, dtype=REC,
                        columnar=True)
    for s in sorted(sset.shards):
        sh.map_shard(sset, s, key_fn=lambda r: r["key"], key_field="key")
    sh.finish_maps()
    cols, n = sh.pull_columns(0, materialize=False, verify=True)
    assert n == sum(svc.partition_records[0]
                    for svc in sh._services.values())
    # map-side partition sets survive the pull (release is deferred) ...
    for svc in sh._services.values():
        assert svc.partition_sets[0].name in svc.pool.paging.sets
    sh.release_reducer(0)
    # ... and drop on release_reducer
    for svc in sh._services.values():
        assert svc.partition_sets[0].name not in svc.pool.paging.sets
    cluster.shutdown()


def test_pull_columns_crc_failure_raises_and_repull_succeeds():
    cluster = Cluster(4, node_capacity=32 << 20, page_size=1 << 16,
                      replication_factor=0)
    recs = _recs(4000, seed=13)
    sset = cluster.create_sharded_set(
        "s", recs, key_fn=lambda r: r["key"],
        attrs_factory=columnar_job_data_attrs)
    sh = ClusterShuffle(cluster, "sh", num_reducers=4, dtype=REC,
                        columnar=True)
    for s in sorted(sset.shards):
        sh.map_shard(sset, s, key_fn=lambda r: r["key"], key_field="key")
    sh.finish_maps()
    # corrupt one landed key byte on a map node that received partition 0
    svc = next(s for s in sh._services.values()
               if s.partition_records[0] > 0)
    ls = svc.partition_sets[0]
    layout = ColumnLayout.for_page(REC, ls.page_size)
    page = ls.pages[min(ls.pages)]
    view = svc.pool.pin(page)
    view[layout.field_offs["key"]] ^= 0xFF
    svc.pool.unpin(page, dirty=True)
    with pytest.raises(ValueError, match="CRC"):
        sh.pull_columns(0)
    # deferred release left the map output intact: undo the flip, re-pull
    view = svc.pool.pin(page)
    view[layout.field_offs["key"]] ^= 0xFF
    svc.pool.unpin(page, dirty=True)
    cols, n = sh.pull_columns(0)
    assert n == sum(s.partition_records[0] for s in sh._services.values())
    cluster.shutdown()


# -- shuffle -> aggregate -> join property sweep ------------------------------
@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1), st.booleans())
def test_pipeline_columnar_vs_row_property(seed, overcap):
    """The full pipeline — shuffle-backed aggregation plus a distributed
    join — must produce canonical-sort-identical records and equal content
    checksums under either storage scheme, with pools over capacity on some
    examples so the spill path is part of the property."""
    n = 3000
    a = _pairs(n, key_range=40, seed=seed)
    b = _pairs(n // 2, key_range=40, seed=seed + 1)
    results = {}
    for columnar in (False, True):
        cap = (256 << 10) if overcap else (32 << 20)
        cluster = Cluster(4, node_capacity=cap, page_size=1 << 13,
                          replication_factor=0)
        af = columnar_job_data_attrs if columnar else None
        sa = cluster.create_sharded_set("a", a, key_fn=lambda r: r["key"],
                                        attrs_factory=af)
        sb = cluster.create_sharded_set("b", b, key_fn=lambda r: r["key"],
                                        attrs_factory=af)
        gk, gv = cluster_hash_aggregate(cluster, sa, "key", "val",
                                        hash_page_size=1 << 13,
                                        force_shuffle=True)
        order = np.argsort(gk)
        joined, _report = cluster_join(cluster, sa, sb, "key",
                                       page_size=1 << 13)
        results[columnar] = (gk[order], gv[order], joined)
        cluster.shutdown()
    (rk, rv, rj), (ck, cv, cj) = results[False], results[True]
    assert np.array_equal(rk, ck)
    assert np.array_equal(rv, cv)          # integer-valued: exact
    assert np.array_equal(rj, cj)          # both canonical-sorted
    assert record_content_checksum(rj) == record_content_checksum(cj)


# -- pagelog fsync policy knob ------------------------------------------------
def test_fsync_policy_validated():
    with pytest.raises(ValueError, match="fsync_policy"):
        PageLog("/tmp/never-created", fsync_policy="wat")


def test_fsync_default_none_never_syncs(tmp_path):
    log = PageLog(str(tmp_path))
    assert log.fsync_policy == "none"
    for _ in range(8):
        log.append("s", os.urandom(256))
    log.close()
    assert log.fsync_count == 0


def test_fsync_always_syncs_every_append(tmp_path):
    log = PageLog(str(tmp_path), fsync_policy="always")
    for _ in range(5):
        log.append("s", os.urandom(256))
    assert log.fsync_count == 5
    log.close()


def test_fsync_close_syncs_only_at_close(tmp_path):
    log = PageLog(str(tmp_path), fsync_policy="close")
    for _ in range(5):
        log.append("s", os.urandom(256))
    assert log.fsync_count == 0
    log.close()
    assert log.fsync_count == 1


def test_fsync_group_batches_syncs(tmp_path):
    log = PageLog(str(tmp_path), fsync_policy="group", group_bytes=4096)
    for _ in range(16):
        log.append("s", os.urandom(1024))
    # batched: far fewer syncs than appends, but the threshold did trip
    assert 0 < log.fsync_count < 16
    mid = log.fsync_count
    log.close()                       # unsynced tail drains on clean close
    assert log.fsync_count >= mid


@pytest.mark.parametrize("policy", FSYNC_POLICIES)
def test_fsck_clean_under_each_fsync_policy(tmp_path, policy):
    log = PageLog(str(tmp_path), fsync_policy=policy, group_bytes=1024)
    for i in range(6):
        log.append(f"s{i % 2}", os.urandom(512))
    log.close()
    rep = fsck(str(tmp_path))
    assert rep["clean"] and rep["records"] == 6
    assert rep["live_sets"] == ["s0", "s1"]
