"""Data pipeline on the buffer pool + dataset replicas."""
import numpy as np

from repro.core import BufferPool, PartitionScheme, StatisticsDB
from repro.data.pipeline import (BatchLoader, register_dataset_replicas,
                                 synthetic_token_dataset)


def test_loader_batches_and_labels():
    pool = BufferPool(32 << 20)
    ds = synthetic_token_dataset(pool, "d", vocab=500, num_sequences=48,
                                 seq_len=16)
    batches = list(BatchLoader(ds, batch_size=16))
    assert len(batches) == 3
    for b in batches:
        assert b["tokens"].shape == (16, 16)
        assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
        assert (b["labels"][:, -1] == -100).all()


def test_loader_through_spill():
    pool = BufferPool(1 << 20)
    ds = synthetic_token_dataset(pool, "big", vocab=500, num_sequences=4096,
                                 seq_len=64)
    assert pool.stats["spill_bytes"] > 0
    n = 0
    seen = set()
    for b in BatchLoader(ds, batch_size=128):
        n += len(b["tokens"])
        seen.add(int(b["tokens"][0, 0]))
    assert n == 4096


def test_dataset_replicas_registered_and_recoverable():
    stats = StatisticsDB()
    rec = np.zeros(5000, dtype=[("doc", np.int64), ("bucket", np.int64)])
    rec["doc"] = np.arange(5000)
    rec["bucket"] = np.arange(5000) % 7
    schemes = [PartitionScheme("doc", lambda r: r["doc"], 64, 8),
               PartitionScheme("bucket", lambda r: r["bucket"], 64, 8)]
    source, regs = register_dataset_replicas(stats, "corpus", rec, 8, schemes)
    assert len(stats.replicas_of("corpus")) == 3  # source + 2 replicas
    best = stats.best_replica("corpus", "bucket")
    assert best.set_name == "corpus_by_bucket"
    # replica contents complete
    for reg in regs:
        assert reg.target.total_records() == 5000
