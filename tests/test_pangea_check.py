"""pangea-check (tools/pangea_check): rule unit tests, negative-path
seeding, waiver mechanics, and the repo-tree-clean gate."""
import os
import sys
import textwrap

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from tools.pangea_check import RULES, run_check
from tools.pangea_check.__main__ import WAIVER_BUDGET, main
from tools.pangea_check.rules import check_file


def _waiver(rule, reason):
    """A waiver comment, assembled at runtime so this file's own source
    never contains the literal marker (the repo-tree gate below scans it)."""
    return "# pangea: " + f"allow({rule}): {reason}"


def _check(tmp_path, code, name="mod.py"):
    p = tmp_path / name
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(code))
    return check_file(str(p))


def _rules(findings):
    return [f.rule for f in findings]


# -- R1: no pickle outside the rpc escape hatch -------------------------------
def test_r1_flags_pickle_import(tmp_path):
    findings, _ = _check(tmp_path, """\
        import pickle
        """)
    assert _rules(findings) == ["R1"]
    assert "no-pickle" in findings[0].message


def test_r1_flags_from_import_and_dill(tmp_path):
    findings, _ = _check(tmp_path, """\
        from pickle import dumps
        import dill
        """)
    assert sorted(_rules(findings)) == ["R1", "R1"]


def test_r1_exempts_the_rpc_module(tmp_path):
    findings, _ = _check(tmp_path, "import pickle\n",
                         name="repro/runtime/rpc.py")
    assert findings == []


# -- R4: bare locks -----------------------------------------------------------
def test_r4_flags_bare_threading_locks(tmp_path):
    findings, _ = _check(tmp_path, """\
        import threading
        a = threading.Lock()
        b = threading.RLock()
        c = threading.Condition(a)
        """)
    assert _rules(findings) == ["R4", "R4", "R4"]


def test_r4_accepts_tracked_factories(tmp_path):
    findings, _ = _check(tmp_path, """\
        from repro.core.sanitizer import tracked_lock, tracked_condition
        a = tracked_lock("x")
        c = tracked_condition("x.cv", a)
        """)
    assert findings == []


def test_r4_exempts_the_sanitizer_module(tmp_path):
    findings, _ = _check(tmp_path, "import threading\nL = threading.Lock()\n",
                         name="repro/core/sanitizer.py")
    assert findings == []


# -- R6 / R7 ------------------------------------------------------------------
def test_r6_flags_bare_except(tmp_path):
    findings, _ = _check(tmp_path, """\
        try:
            x = 1
        except:
            pass
        """)
    assert _rules(findings) == ["R6"]


def test_r7_flags_swallowed_importerror(tmp_path):
    findings, _ = _check(tmp_path, """\
        try:
            import numpy
        except ImportError:
            pass
        """)
    assert _rules(findings) == ["R7"]


def test_r7_accepts_handler_with_a_real_fallback(tmp_path):
    findings, _ = _check(tmp_path, """\
        try:
            import numpy as np
        except ImportError:
            np = None
        """)
    assert findings == []


# -- R3: blocking under a lock ------------------------------------------------
def test_r3_flags_sleep_under_lock(tmp_path):
    findings, _ = _check(tmp_path, """\
        import time
        def f(self):
            with self._lock:
                time.sleep(1)
        """)
    assert _rules(findings) == ["R3"]
    assert "self._lock" in findings[0].message


def test_r3_flags_fsync_and_socket_ops(tmp_path):
    findings, _ = _check(tmp_path, """\
        import os
        def f(self, sock):
            with self._lock:
                os.fsync(3)
                sock.sendall(b"x")
                sock.recv(4)
        """)
    assert _rules(findings) == ["R3", "R3", "R3"]


def test_r3_exempts_wait_on_the_held_condition(tmp_path):
    findings, _ = _check(tmp_path, """\
        def f(self):
            with self._cv:
                self._cv.wait_for(lambda: True, timeout=1.0)
        """)
    assert findings == []


def test_r3_flags_wait_on_a_different_object(tmp_path):
    findings, _ = _check(tmp_path, """\
        def f(self, other):
            with self._lock:
                other.wait(1.0)
        """)
    assert _rules(findings) == ["R3"]


def test_r3_nested_function_bodies_run_outside_the_lock(tmp_path):
    findings, _ = _check(tmp_path, """\
        import time
        def f(self):
            with self._lock:
                def later():
                    time.sleep(1)
                return later
        """)
    assert findings == []


def test_r3_exempts_polls_and_path_joins(tmp_path):
    findings, _ = _check(tmp_path, """\
        import os
        def f(self, fut):
            with self._lock:
                fut.result(timeout=0)
                p = os.path.join("a", "b")
                s = ",".join(["a"])
        """)
    assert findings == []


# -- R2 / R5: leaked grants ---------------------------------------------------
def test_r2_flags_discarded_reserve_result(tmp_path):
    findings, _ = _check(tmp_path, """\
        def f(memory):
            memory.reserve(100)
        """)
    assert _rules(findings) == ["R2"]
    assert "discarded" in findings[0].message


def test_r2_flags_assigned_but_never_released_grant(tmp_path):
    findings, _ = _check(tmp_path, """\
        def f(memory):
            res = memory.try_reserve(100, urgency="low")
            return None
        """)
    assert _rules(findings) == ["R2"]


def test_r2_accepts_context_managed_release_and_handoff(tmp_path):
    findings, _ = _check(tmp_path, """\
        def a(memory):
            with memory.reserve(100):
                pass
        def b(memory):
            res = memory.try_reserve(100)
            if res is not None:
                res.release()
        def c(memory):
            res = memory.reserve(100)
            return res
        def d(memory, table):
            res = memory.reserve(100)
            table["k"] = (1, res)
        def e(memory, sink):
            res = memory.reserve(100)
            sink.adopt(res)
        """)
    assert findings == []


def test_r5_flags_discarded_arena_descriptor(tmp_path):
    findings, _ = _check(tmp_path, """\
        def f(arena, payload):
            arena.put(payload)
        """)
    assert _rules(findings) == ["R5"]


def test_r5_accepts_freed_or_handed_off_descriptor(tmp_path):
    findings, _ = _check(tmp_path, """\
        def a(arena, payload):
            desc = arena.put(payload)
            arena.free(desc)
        def b(outbox, payload):
            desc = outbox.put(payload)
            return desc
        """)
    assert findings == []


# -- waivers ------------------------------------------------------------------
def test_waiver_on_the_finding_line_suppresses_it(tmp_path):
    findings, waivers = _check(tmp_path, f"""\
        import time
        def f(self):
            with self._lock:
                time.sleep(1)  {_waiver("R3", "test fixture needs it")}
        """)
    assert findings[0].waived
    assert findings[0].waiver_reason == "test fixture needs it"
    assert all(w.used for w in waivers)


def test_waiver_on_the_line_above_suppresses_it(tmp_path):
    findings, _ = _check(tmp_path, f"""\
        import time
        def f(self):
            with self._lock:
                {_waiver("R3", "justified here")}
                time.sleep(1)
        """)
    assert findings[0].waived


def test_wrong_rule_waiver_does_not_suppress_and_is_stale(tmp_path):
    findings, waivers = _check(tmp_path, f"""\
        import time
        def f(self):
            with self._lock:
                time.sleep(1)  {_waiver("R1", "wrong rule named")}
        """)
    assert not findings[0].waived
    assert [w for w in waivers if not w.used]


# -- negative-path seeding through the CLI (findings by name) -----------------
def test_seeded_pickle_violation_is_caught_by_name(tmp_path, capsys):
    bad = tmp_path / "sneaky.py"
    bad.write_text("import pickle\nblob = pickle.dumps([1])\n")
    assert main([str(bad), "--strict"]) == 1
    out = capsys.readouterr().out
    assert "R1" in out and "no-pickle" in out


def test_seeded_reservation_leak_is_caught_by_name(tmp_path, capsys):
    bad = tmp_path / "leaky.py"
    bad.write_text(textwrap.dedent("""\
        def stage(memory):
            grant = memory.try_reserve(1 << 20, urgency="normal")
            return True
        """))
    assert main([str(bad), "--strict"]) == 1
    out = capsys.readouterr().out
    assert "R2" in out and "reservation-leak" in out and "grant" in out


def test_clean_file_passes_strict(tmp_path):
    good = tmp_path / "fine.py"
    good.write_text("def f():\n    return 1\n")
    assert main([str(good), "--strict"]) == 0


def test_strict_fails_on_stale_waiver(tmp_path, capsys):
    f = tmp_path / "stale.py"
    f.write_text(f"x = 1  {_waiver('R3', 'nothing here needs this')}\n")
    assert main([str(f), "--strict"]) == 1
    assert "stale waiver" in capsys.readouterr().out


def test_strict_fails_over_waiver_budget(tmp_path):
    f = tmp_path / "budget.py"
    f.write_text(textwrap.dedent(f"""\
        import time
        def f(self):
            with self._lock:
                time.sleep(1)  {_waiver("R3", "one")}
                time.sleep(2)  {_waiver("R3", "two")}
        """))
    assert main([str(f), "--strict", "--max-waivers", "1"]) == 1
    assert main([str(f), "--strict", "--max-waivers", "2"]) == 0


# -- the repo-tree gate -------------------------------------------------------
def test_repo_tree_is_clean_and_within_waiver_budget():
    result = run_check([os.path.join(_ROOT, "src"),
                        os.path.join(_ROOT, "tests")])
    assert result.files_checked > 50
    assert result.findings == [], [str(f) for f in result.findings]
    assert result.stale_waivers == [], \
        [(w.path, w.line, w.rule) for w in result.stale_waivers]
    assert result.waivers_used <= WAIVER_BUDGET


def test_rule_table_documents_every_emitted_rule():
    assert set(RULES) == {"R1", "R2", "R3", "R4", "R5", "R6", "R7"}
