"""Cluster-wide memory pressure (ISSUE 3): the per-node MemoryManager that
owns eviction policy, the spill-capable distributed shuffle, the streaming
remesh, and scheduler recovery-source costing.

Acceptance scenarios:
* a cluster shuffle whose total map output is >= 2x per-node pool capacity
  completes with byte-identical aggregation results vs the in-memory path,
  with nonzero spill counted in ``memory_report``;
* ``remesh_degrade`` peak driver-side buffering stays O(page) (asserted via
  MemoryManager high-water accounting) while producing the same post-remesh
  shard contents as the gather-based path;
* ``recover_node`` picks the cheapest costed source (asserted via
  ``RecoveryReport.sources``), including the co-partitioned rebuild through
  ``core/replication.recover_target_shard`` when no chain replica survives.
"""
import threading
import zlib

import numpy as np
import pytest

from repro.core import (BufferPool, MemoryManager, SpillStore,
                        combine_content_checksums, record_content_checksum,
                        shard_checksum)
from repro.runtime.cluster import (Cluster, ClusterShuffle,
                                   cluster_hash_aggregate)

PAIR = np.dtype([("key", np.int64), ("val", np.float64)])
REC2 = np.dtype([("key", np.int64), ("key2", np.int64), ("val", np.float64)])


def _pairs(n, key_range, seed=0):
    rng = np.random.default_rng(seed)
    recs = np.zeros(n, PAIR)
    recs["key"] = rng.integers(0, key_range, n)
    recs["val"] = rng.random(n)
    return recs


def _oracle(recs):
    uk, inv = np.unique(recs["key"], return_inverse=True)
    out = np.zeros(len(uk))
    np.add.at(out, inv, recs["val"])
    return uk, out


# -- MemoryManager accounting -------------------------------------------------
def test_memory_manager_tracks_resident_pinned_spilled():
    pool = BufferPool(1 << 16)
    mm = pool.memory
    ls = pool.create_set("a", 8192)
    p1 = pool.new_page(ls)                       # allocated + pinned
    assert mm.resident_bytes == 8192 and mm.pinned_bytes == 8192
    pool.unpin(p1, dirty=True)
    assert mm.pinned_bytes == 0 and mm.resident_bytes == 8192
    # overflow the pool so p1 spills
    others = [pool.new_page(ls) for _ in range(7)]
    for p in others:
        pool.unpin(p, dirty=True)
    extra = pool.new_page(pool.create_set("b", 8192))
    pool.unpin(extra, dirty=True)
    assert mm.spilled_bytes > 0
    assert mm.stats["spill_bytes"] > 0
    spilled_before = mm.spilled_bytes
    victim = next(p for p in ls.pages.values() if p.spilled and not p.resident)
    pool.pin(victim)                             # fault back in (image stays)
    pool.unpin(victim)
    assert mm.stats["fetch_bytes"] >= 8192
    # faulting one page in pages another out of the over-committed pool
    assert mm.spilled_bytes > 0
    assert mm.spilled_bytes == sum(
        p.size for lset in (ls, pool.get_set("b"))
        for p in lset.pages.values() if p.spilled and not p.resident)
    # high-water marks are monotone and at least the live peaks
    assert mm.resident_hwm >= mm.resident_bytes
    assert mm.pinned_hwm >= 8192
    pool.drop_set(ls)
    pool.drop_set(pool.get_set("b"))
    assert mm.resident_bytes == 0 and mm.spilled_bytes == 0


def test_memory_manager_reserve_and_pressure():
    mm = MemoryManager(1 << 20, pressure_watermark=0.5)
    assert not mm.under_pressure() and mm.pressure_score() == 0.0
    with mm.reserve(700 << 10) as res:
        assert mm.under_pressure()
        assert 0.0 < mm.pressure_score() <= 1.0
        assert mm.reserved_bytes == 700 << 10
    assert mm.reserved_bytes == 0
    assert mm.reserved_hwm == 700 << 10          # HWM survives the release
    assert not mm.under_pressure()
    res.release()                                # double release is a no-op
    assert mm.reserved_bytes == 0


def test_write_through_copies_are_not_pressure():
    """Regression: write-through durability copies hit the spill store but
    the pages stay resident — they must not read as memory pressure."""
    from repro.data.pipeline import user_data_attrs
    pool = BufferPool(1 << 16)
    ls = pool.create_set("user", 8192, user_data_attrs())
    for i in range(4):                           # half the pool, persisted
        p = pool.new_page(ls)
        pool.view(p)[:] = i
        pool.unpin(p, dirty=True)
    assert pool.stats["spill_bytes"] > 0         # durability copies written
    assert pool.memory.spilled_bytes == 0        # nothing paged out
    assert not pool.memory.under_pressure()
    assert pool.memory.pressure_score() == 0.0
    pool.drop_set(ls)                            # images still cleaned up
    assert pool.spill.held_page_ids() == set()
    assert pool.memory.spilled_bytes == 0


def test_remesh_driver_peak_is_per_call_window():
    """Regression: driver_peak_bytes must measure THIS remesh, not the
    driver manager's lifetime high-water mark."""
    recs = _pairs(30_000, 1_000, seed=21)
    cluster = Cluster(4, node_capacity=16 << 20, page_size=1 << 14,
                      replication_factor=1)
    sset = cluster.create_sharded_set("t", recs, key_fn=lambda r: r["key"])
    with cluster.driver_memory.reserve(64 << 20):  # earlier O(dataset) stager
        pass
    cluster.kill_node(2)
    report = cluster.remesh_degrade(streaming=True)
    assert report.ok
    assert report.driver_peak_bytes <= 2 * cluster.page_size
    back = cluster.read_sharded(sset)
    assert np.array_equal(np.sort(back["key"]), np.sort(recs["key"]))
    cluster.shutdown()


def test_pool_views_delegate_to_manager():
    """pool.paging / pool.spill / pool.stats are the manager's objects."""
    pool = BufferPool(1 << 16, policy="lru")
    assert pool.paging is pool.memory.paging
    assert pool.spill is pool.memory.spill
    assert pool.stats is pool.memory.stats
    assert pool.memory.policy == "lru"


# -- content checksum (order-independent shard fingerprint) -------------------
def test_content_checksum_is_order_independent_and_chunkable():
    recs = _pairs(5_000, 200, seed=3)
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(recs))
    assert record_content_checksum(recs) == record_content_checksum(recs[perm])
    parts = [record_content_checksum(recs[i:i + 777])
             for i in range(0, len(recs), 777)]
    assert combine_content_checksums(parts) == record_content_checksum(recs)
    # duplicate-sensitive: doubling a record changes the fingerprint
    assert record_content_checksum(np.concatenate([recs, recs[:1]])) != \
        record_content_checksum(recs)


# -- spill-store lifecycle (satellite bugfix) ---------------------------------
def test_drop_set_deletes_spill_images(tmp_path):
    """Regression: dropping a set must delete its spilled pages from the
    SpillStore — on disk and in memory — not just free its arena pages."""
    pool = BufferPool(1 << 16, SpillStore(str(tmp_path)))
    ls_a = pool.create_set("a", 8192)
    ls_b = pool.create_set("b", 8192)
    for ls in (ls_a, ls_b):
        for i in range(6):                       # 96K through a 64K pool
            p = pool.new_page(ls)
            pool.view(p)[:] = i
            pool.unpin(p, dirty=True)
    assert pool.spill.held_page_ids()            # something spilled
    assert list(tmp_path.iterdir())
    pool.drop_set(ls_a)
    pool.drop_set(ls_b)
    assert pool.spill.held_page_ids() == set()
    assert list(tmp_path.iterdir()) == []
    assert pool.memory.spilled_bytes == 0


def test_kill_node_deletes_spill_files(tmp_path):
    """A dead machine's local disk is gone: killing a node must not leave its
    spill files behind (they used to leak under a real spill_dir)."""
    cluster = Cluster(2, node_capacity=256 << 10, page_size=1 << 14,
                      replication_factor=0, spill_dir=str(tmp_path))
    recs = _pairs(40_000, 100, seed=4)           # 640K through 256K pools
    cluster.create_sharded_set("big", recs, key_fn=lambda r: r["key"])
    node_dirs = [d for d in tmp_path.iterdir() if any(d.iterdir())]
    assert node_dirs                             # staging really spilled
    cluster.kill_node(0)
    leaked = list((tmp_path / "node0").iterdir())
    assert leaked == []
    cluster.shutdown()


# -- over-capacity distributed shuffle (acceptance #3) ------------------------
def _shuffle_aggregate(recs, node_capacity, policy="data-aware"):
    cluster = Cluster(4, node_capacity=node_capacity, page_size=1 << 14,
                      replication_factor=0, policy=policy)
    sset = cluster.create_sharded_set("src", recs, key_fn=lambda r: r["key"])
    keys, vals = cluster_hash_aggregate(cluster, sset, "key", "val",
                                        num_reducers=4, force_shuffle=True)
    cluster.shutdown()
    return (keys, vals), cluster


def test_over_capacity_shuffle_matches_in_memory_bitwise():
    n = 60_000                                   # 960K of pairs
    recs = _pairs(n, 1 << 40, seed=5)
    small_cap = 384 << 10                        # map output >= 2x capacity
    assert recs.nbytes >= 2 * small_cap
    (bk, bv), big = _shuffle_aggregate(recs, 64 << 20)
    (sk, sv), small = _shuffle_aggregate(recs, small_cap)
    # byte-identical results vs the in-memory path
    assert np.array_equal(bk, sk)
    assert np.array_equal(bv.view(np.uint64), sv.view(np.uint64))
    uk, ov = _oracle(recs)
    assert np.array_equal(sk, uk)
    np.testing.assert_allclose(sv, ov, rtol=1e-9)
    # the big pool never paged; the small one spilled and it is visible in
    # both the per-set memory_report and the managers' pressure accounting
    def total_spill(c):
        return sum(s.get("spill_bytes", 0)
                   for node in c.memory_report().values()
                   for s in node.values())
    assert total_spill(big) == 0
    assert total_spill(small) > 0
    assert sum(node.memory.stats["spill_bytes"]
               for node in small.nodes.values()) > 0
    assert any(node.memory.stats["fetch_bytes"] > 0
               for node in small.nodes.values())


def test_over_capacity_shuffle_under_lru_baseline_also_correct():
    recs = _pairs(30_000, 1 << 40, seed=6)
    (k1, v1), _ = _shuffle_aggregate(recs, 384 << 10, policy="lru")
    uk, ov = _oracle(recs)
    assert np.array_equal(k1, uk)
    np.testing.assert_allclose(v1, ov, rtol=1e-9)


def test_finish_maps_publishes_node_pressure():
    cluster = Cluster(4, node_capacity=256 << 10, page_size=1 << 14,
                      replication_factor=0)
    recs = _pairs(40_000, 1 << 40, seed=7)
    sset = cluster.create_sharded_set("src", recs, key_fn=lambda r: r["key"])
    sh = ClusterShuffle(cluster, "sh", num_reducers=4, dtype=PAIR)
    sh.map_sharded(sset, key_fn=lambda r: r["key"])
    sh.finish_maps()
    pressures = cluster.stats.node_pressure_map()
    assert pressures and all(0.0 <= p <= 1.0 for p in pressures.values())
    assert any(p > 0 for p in pressures.values())   # the pools really paged
    cluster.shutdown()


def test_place_reducers_penalizes_pressured_nodes():
    cluster = Cluster(4, node_capacity=16 << 20, page_size=1 << 16,
                      replication_factor=0)
    sh = ClusterShuffle(cluster, "p", num_reducers=1, dtype=PAIR)
    probe = np.arange(50_000, dtype=np.int64)
    keys0 = probe[sh.partition_of_keys(probe) == 0]
    heavy = np.zeros(3_000, PAIR)
    heavy["key"] = keys0[:1][0]
    light = np.zeros(500, PAIR)
    light["key"] = keys0[:1][0]
    sh.map_batch(1, heavy, key_fn=lambda p: p["key"])
    sh.map_batch(2, light, key_fn=lambda p: p["key"])
    sh.finish_maps()
    assert cluster.scheduler.place_reducers("p", 1)[0] == 1  # byte-heaviest
    # with node 1 reported as fully pressured, its locality is worth nothing
    cluster.stats.record_node_pressure(1, 1.0)
    assert cluster.scheduler.place_reducers("p", 1)[0] == 2
    cluster.shutdown()


# -- streaming remesh (acceptance #4) -----------------------------------------
def _remesh_cluster(recs, streaming):
    cluster = Cluster(4, node_capacity=16 << 20, page_size=1 << 14,
                      replication_factor=1)
    sset = cluster.create_sharded_set("t", recs, key_fn=lambda r: r["key"])
    cluster.kill_node(2)
    report = cluster.remesh_degrade(streaming=streaming)
    return cluster, sset, report


def test_streaming_remesh_matches_gather_with_o_page_driver_memory():
    recs = _pairs(50_000, 3_000, seed=8)
    gc, gs, gr = _remesh_cluster(recs, streaming=False)
    sc, ss, sr = _remesh_cluster(recs, streaming=True)
    assert gr.ok and sr.ok and sr.streamed
    assert sorted(gs.shards) == sorted(ss.shards)
    for nid in gs.shards:
        a = gc.read_shard(gs, nid)
        b = sc.read_shard(ss, nid)
        assert np.array_equal(a.view(np.uint8).reshape(len(a), -1),
                              b.view(np.uint8).reshape(len(b), -1))
        assert gs.shards[nid].checksum == ss.shards[nid].checksum
        assert shard_checksum(b) == ss.shards[nid].checksum
        assert record_content_checksum(b) == ss.shards[nid].content_checksum
    # O(page) driver staging for the stream, O(dataset) for the gather —
    # asserted through the driver MemoryManager's reservation high-water mark
    assert sr.driver_peak_bytes <= 2 * sc.page_size
    assert gr.driver_peak_bytes >= recs.nbytes
    # the streamed bytes are accounted as traffic (the gather path never
    # charged its driver round-trip)
    assert sr.bytes_transferred > 0
    gc.shutdown()
    sc.shutdown()


def test_streaming_remesh_replicas_and_reads_survive():
    recs = _pairs(30_000, 1_000, seed=9)
    cluster, sset, report = _remesh_cluster(recs, streaming=True)
    assert report.ok
    back = cluster.read_sharded(sset)
    assert np.array_equal(np.sort(back["key"]), np.sort(recs["key"]))
    for nid, info in sset.shards.items():
        assert nid in report.node_ids
        for holder, rep_name in info.replicas:
            rep = cluster.nodes[holder].read_records(rep_name, sset.dtype)
            assert shard_checksum(rep) == info.checksum
    cluster.shutdown()


def test_streaming_remesh_under_pool_pressure():
    """Old + staged shards coexist during the stream; with pools sized below
    the dataset the remesh must page, not fail."""
    recs = _pairs(50_000, 3_000, seed=10)        # 800K vs 512K pools
    cluster = Cluster(4, node_capacity=512 << 10, page_size=1 << 14,
                      replication_factor=1)
    sset = cluster.create_sharded_set("t", recs, key_fn=lambda r: r["key"])
    cluster.kill_node(1)
    report = cluster.remesh_degrade(streaming=True)
    assert report.ok
    assert report.driver_peak_bytes <= 2 * cluster.page_size
    back = cluster.read_sharded(sset)
    assert np.array_equal(np.sort(back["key"]), np.sort(recs["key"]))
    assert sum(node.memory.stats["spill_bytes"]
               for node in cluster.nodes.values() if node.alive) > 0
    cluster.shutdown()


def test_streaming_remesh_cleans_staging_on_failure(monkeypatch):
    """A mid-stream failure must drop the @remesh staging sets (leaving the
    old layout intact) so a retried remesh succeeds instead of tripping over
    stale set names."""
    import repro.runtime.cluster as rc
    recs = _pairs(20_000, 500, seed=20)
    cluster = Cluster(4, node_capacity=4 << 20, page_size=1 << 14,
                      replication_factor=1)
    sset = cluster.create_sharded_set("t", recs, key_fn=lambda r: r["key"])
    cluster.kill_node(3)
    orig = rc.dispatch_plan
    calls = {"n": 0}

    def flaky(*args, **kwargs):
        calls["n"] += 1
        if calls["n"] == 3:
            raise RuntimeError("injected mid-stream failure")
        return orig(*args, **kwargs)

    monkeypatch.setattr(rc, "dispatch_plan", flaky)
    with pytest.raises(RuntimeError, match="injected"):
        cluster.remesh_degrade(streaming=True)
    monkeypatch.setattr(rc, "dispatch_plan", orig)
    leftovers = [name for node in cluster.nodes.values() if node.pool
                 for name in node.pool.paging.sets if "@remesh" in name]
    assert leftovers == []
    report = cluster.remesh_degrade(streaming=True)
    assert report.ok and report.resharded == ["t"]
    back = cluster.read_sharded(sset)
    assert np.array_equal(np.sort(back["key"]), np.sort(recs["key"]))
    cluster.shutdown()


# -- recovery source costing (satellite) --------------------------------------
def test_recovery_prefers_least_pressured_replica_holder():
    cluster = Cluster(4, node_capacity=1 << 20, page_size=1 << 14,
                      replication_factor=2)
    recs = _pairs(5_000, 300, seed=11)
    sset = cluster.create_sharded_set("t", recs, key_fn=lambda r: r["key"])
    holders = [h for h, _ in sset.shards[1].replicas]
    assert sorted(holders) == [2, 3]
    # push holder 2 over its watermark so its live pressure is nonzero
    filler = _pairs(58_000, 100, seed=12)        # ~928K of a 1M pool
    cluster.nodes[2].write_records("filler", filler, PAIR, 1 << 14)
    assert cluster.nodes[2].memory.pressure_score() > 0
    assert cluster.nodes[3].memory.pressure_score() == 0
    cluster.kill_node(1)
    plan = cluster.scheduler.recovery_plan(sset, 1, 1)
    # both replica copies cost the same bytes; the tie breaks on pressure
    assert [s.holder for s in plan[:2]] == [3, 2]
    report = cluster.recover_node(1)
    assert report.ok
    assert report.sources["t:1"] == "replica@3"
    cluster.shutdown()


def test_recovery_rebuilds_from_co_partitioned_replica():
    """No chain replica survives, but a heterogeneously partitioned replica
    of the same logical data does: the scheduler costs the rebuild
    (core/replication.recover_target_shard) and recovery executes it,
    verified by the order-independent content checksum."""
    rng = np.random.default_rng(13)
    n = 20_000
    recs = np.zeros(n, REC2)
    recs["key"] = rng.integers(0, 2_000, n)
    recs["key2"] = rng.integers(0, 2_000, n)
    recs["val"] = rng.random(n)
    cluster = Cluster(4, node_capacity=16 << 20, page_size=1 << 14,
                      replication_factor=0)
    a = cluster.create_sharded_set("a", recs, key_fn=lambda r: r["key"],
                                   partition_key="key", replication_factor=0)
    b = cluster.create_sharded_set("b", recs, key_fn=lambda r: r["key2"],
                                   partition_key="key2", replication_factor=1)
    cluster.register_replica_set("a", b)
    order = ["key", "key2", "val"]
    lost = np.sort(cluster.read_shard(a, 1), order=order).copy()
    cluster.kill_node(1)
    report = cluster.recover_node(1)
    assert report.ok
    assert report.sources["a:1"] == "rebuild<-b"     # only viable source
    assert report.sources["b:1"].startswith("replica@")
    rebuilt = cluster.read_shard(a, 1)
    assert np.array_equal(np.sort(rebuilt, order=order), lost)
    # rebuilt order becomes the canonical layout: catalog CRC re-keyed
    assert shard_checksum(rebuilt) == a.shards[1].checksum
    assert record_content_checksum(rebuilt) == a.shards[1].content_checksum
    cluster.shutdown()


def test_recovery_plan_orders_by_cost():
    cluster = Cluster(4, node_capacity=16 << 20, page_size=1 << 14,
                      replication_factor=1)
    recs = _pairs(10_000, 500, seed=14)
    sset = cluster.create_sharded_set("t", recs, key_fn=lambda r: r["key"])
    # recovering shard 1 onto its own replica holder is free; onto any other
    # node it costs the shard's bytes
    holder = sset.shards[1].replicas[0][0]
    plan_home = cluster.scheduler.recovery_plan(sset, 1, target_node=1)
    plan_onto_holder = cluster.scheduler.recovery_plan(sset, 1,
                                                       target_node=holder)
    shard_bytes = sset.shards[1].num_records * sset.dtype.itemsize
    assert plan_home[0].cost_bytes in (0, shard_bytes)  # primary alive: free
    rep = next(s for s in plan_onto_holder if s.kind == "replica")
    assert rep.cost_bytes == 0                   # bytes already on the target
    cluster.shutdown()


# -- spill/fault under concurrency (satellite) --------------------------------
THREADS = 6
ROUNDS = 60


def test_concurrent_pin_spill_fault_preserves_crc():
    """Threads pin, rewrite, and fault pages of the same locality set while
    an undersized pool forces constant eviction; every page's content must
    match the CRC its owner recorded, at every read and at the end."""
    pool = BufferPool(1 << 18)                   # 256K
    ls = pool.create_set("shared", 1 << 14)      # 16K pages
    n_pages = 24                                 # 384K: never all resident
    pages = []
    crcs = {}
    rng = np.random.default_rng(0)
    for i in range(n_pages):
        p = pool.new_page(ls)
        data = rng.integers(0, 256, p.size, dtype=np.uint8)
        pool.view(p)[:] = data
        crcs[p.page_id] = zlib.crc32(data.tobytes())
        pool.unpin(p, dirty=True)
        pages.append(p)
    errors = []
    barrier = threading.Barrier(THREADS)

    def worker(tid):
        trng = np.random.default_rng(100 + tid)
        mine = pages[tid::THREADS]               # disjoint ownership
        barrier.wait()
        try:
            for r in range(ROUNDS):
                p = mine[int(trng.integers(0, len(mine)))]
                view = pool.pin(p)
                try:
                    got = zlib.crc32(view.tobytes())
                    if got != crcs[p.page_id]:
                        errors.append(
                            f"page {p.page_id}: crc {got:#x} != "
                            f"{crcs[p.page_id]:#x} (round {r})")
                        return
                    fresh = trng.integers(0, 256, p.size, dtype=np.uint8)
                    view[:] = fresh
                    crcs[p.page_id] = zlib.crc32(fresh.tobytes())
                finally:
                    pool.unpin(p, dirty=True)
        except Exception as e:  # noqa: BLE001 - surface any thread crash
            errors.append(repr(e))

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errors == []
    assert pool.stats["spill_bytes"] > 0         # eviction really ran
    assert pool.stats["fetch_bytes"] > 0         # pages really faulted
    for p in pages:                              # final sweep
        view = pool.pin(p)
        try:
            assert zlib.crc32(view.tobytes()) == crcs[p.page_id]
        finally:
            pool.unpin(p)
    assert pool.memory.pinned_bytes == 0


def test_concurrent_shuffle_pull_with_spill():
    """Async reducer pulls against spilled map output: the engine's workers
    fault pages back through multiple pools concurrently."""
    cluster = Cluster(4, node_capacity=384 << 10, page_size=1 << 14,
                      replication_factor=0)
    recs = _pairs(50_000, 1 << 40, seed=15)
    sset = cluster.create_sharded_set("src", recs, key_fn=lambda r: r["key"])
    sh = ClusterShuffle(cluster, "sh", num_reducers=8, dtype=PAIR)
    sh.map_sharded(sset, key_fn=lambda r: r["key"])
    sh.finish_maps()
    sh.place_reducers_locally()
    futs = [sh.pull_async(r) for r in range(8)]
    pulled = [f.result(timeout=60) for f in futs]
    allk = np.concatenate([p["key"] for p in pulled])
    assert len(allk) == len(recs)
    assert np.array_equal(np.sort(allk), np.sort(recs["key"]))
    for r in range(8):
        sh.release_reducer(r)
    cluster.shutdown()
