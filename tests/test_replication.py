"""Heterogeneous replication + recovery (paper §7), incl. the N/K law."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (PartitionScheme, StatisticsDB, expected_conflicts,
                        fail_node, partition_set, random_dispatch,
                        recover_source_shard, recover_target_shard,
                        register_replica)

REC = np.dtype([("okey", np.int64), ("pkey", np.int64)])


def _records(n, seed=0):
    rng = np.random.default_rng(seed)
    r = np.zeros(n, REC)
    r["okey"] = rng.permutation(n)
    r["pkey"] = rng.integers(0, max(n // 10, 1), n)
    return r


def test_partition_preserves_all_objects():
    recs = _records(10_000)
    src = random_dispatch("t", recs, 8)
    scheme = PartitionScheme("okey", lambda r: r["okey"], 64, 8)
    tgt = partition_set(src, "t_pt", scheme)
    assert tgt.total_records() == 10_000
    assert np.array_equal(np.sort(tgt.all_records()["okey"]),
                          np.sort(recs["okey"]))
    # placement actually follows the scheme
    for node, shard in tgt.shards.items():
        if len(shard):
            assert (scheme.node_of_records(shard) == node).all()


def test_recover_target_shard_exact():
    recs = _records(20_000, seed=1)
    src = random_dispatch("t", recs, 10, seed=2)
    scheme = PartitionScheme("okey", lambda r: r["okey"], 100, 10)
    tgt = partition_set(src, "t_pt", scheme)
    reg = register_replica(src, tgt, scheme)
    lost = np.sort(tgt.shards[4]["okey"]).copy()
    fail_node(src, 4)
    fail_node(tgt, 4)
    rec = recover_target_shard(reg, 4)
    assert np.array_equal(np.sort(rec["okey"]), lost)


def test_recover_source_shard_exact():
    recs = _records(20_000, seed=3)
    rng = np.random.default_rng(4)
    nodes = rng.integers(0, 10, len(recs))
    src = random_dispatch("t", recs, 10, seed=4)
    scheme = PartitionScheme("okey", lambda r: r["okey"], 100, 10)
    tgt = partition_set(src, "t_pt", scheme)
    reg = register_replica(src, tgt, scheme)
    # record the dispatch map (okey -> source node) for recovery
    okey_to_node = {}
    for node, shard in src.shards.items():
        for k in shard["okey"].tolist():
            okey_to_node[k] = node
    lost = np.sort(src.shards[7]["okey"]).copy()
    fail_node(src, 7)
    fail_node(tgt, 7)
    placement = lambda r: np.array([okey_to_node[k]
                                    for k in r["okey"].tolist()])
    rec = recover_source_shard(reg, 7, placement)
    assert np.array_equal(np.sort(rec["okey"]), lost)


def test_conflicting_objects_follow_nk_law():
    """E[#conflicts] = N/K (paper §7); check within 3 sigma for binomial."""
    n, k = 100_000, 10
    recs = _records(n, seed=5)
    src = random_dispatch("t", recs, k, seed=6)
    scheme = PartitionScheme("okey", lambda r: r["okey"], 1000, k)
    tgt = partition_set(src, "t_pt", scheme)
    reg = register_replica(src, tgt, scheme)
    exp = expected_conflicts(n, k)
    sigma = (n * (1 / k) * (1 - 1 / k)) ** 0.5
    assert abs(reg.num_conflicting - exp) < 4 * sigma


def test_conflicts_decline_with_more_nodes():
    n = 30_000
    recs = _records(n, seed=7)
    counts = []
    for k in (5, 10, 20):
        src = random_dispatch("t", recs, k, seed=8)
        scheme = PartitionScheme("okey", lambda r: r["okey"], 200, k)
        tgt = partition_set(src, f"t_{k}", scheme)
        counts.append(register_replica(src, tgt, scheme).num_conflicting)
    assert counts[0] > counts[1] > counts[2]


def test_statistics_best_replica_selection():
    stats = StatisticsDB()
    recs = _records(1000)
    src = random_dispatch("lineitem", recs, 4)
    stats.register_replica("lineitem", __import__(
        "repro.core.statistics", fromlist=["ReplicaInfo"]).ReplicaInfo(
        set_name="lineitem", partition_key=None, num_partitions=4,
        num_nodes=4))
    for key in ("okey", "pkey"):
        scheme = PartitionScheme(key, lambda r, k=key: r[k], 16, 4)
        tgt = partition_set(src, f"lineitem_{key}", scheme)
        register_replica(src, tgt, scheme, stats, "lineitem")
    best = stats.best_replica("lineitem", "pkey")
    assert best.set_name == "lineitem_pkey"
    fallback = stats.best_replica("lineitem", "no_such_key")
    assert fallback.partition_key is None  # source set


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 12), st.integers(100, 3000))
def test_property_recovery_any_node(k, n):
    recs = _records(n, seed=n)
    src = random_dispatch("t", recs, k, seed=k)
    scheme = PartitionScheme("okey", lambda r: r["okey"], 4 * k, k)
    tgt = partition_set(src, "t_pt", scheme)
    reg = register_replica(src, tgt, scheme)
    node = n % k
    lost = np.sort(tgt.shards[node]["okey"]).copy()
    fail_node(src, node)
    fail_node(tgt, node)
    rec = recover_target_shard(reg, node)
    assert np.array_equal(np.sort(rec["okey"]), lost)
