"""Suite-wide setup: make `hypothesis` importable even when not installed.

Must run before test modules are collected, which conftest import order
guarantees. With the real package present this is a no-op.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

from _hypothesis_compat import install

HYPOTHESIS_SHIMMED = install()
