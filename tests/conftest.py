"""Suite-wide setup: make `hypothesis` importable even when not installed.

Must run before test modules are collected, which conftest import order
guarantees. With the real package present this is a no-op.

Also (PR 10) the per-test isolation fixture: process-global wire counters
are zeroed before every test so assertions are deltas, not order-dependent
residue; and when the runtime sanitizer is on (``PANGEA_SANITIZE=1``) its
state is reset per test and every test asserts it finished with zero
lock-order / blocking-while-holding violations.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
_SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

import pytest

from _hypothesis_compat import install

HYPOTHESIS_SHIMMED = install()

from repro.core import sanitizer as _sanitizer
from repro.runtime import rpc as _rpc


@pytest.fixture(autouse=True)
def _pangea_isolation(request):
    """Counter + sanitizer isolation around every test."""
    _rpc.reset_counters()
    if _sanitizer.enabled():
        _sanitizer.reset()
    yield
    if _sanitizer.enabled():
        _sanitizer.assert_clean(request.node.nodeid)
