"""Distributed paged-KV serving tier (runtime/serving.py): cluster-sharded
sequences, continuous-batching admission, three-level spill, and the
fault-injection sweep — SIGKILL/kill_node at every serving phase boundary,
on both backends."""
import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import PagedKVCache
from repro.runtime.cluster import Cluster, DeadNodeError
from repro.runtime.serving import ServingTier, expected_page_slab

BACKENDS = ("inproc", "proc")


def _cluster(backend, tmp_path=None, **kw):
    kw.setdefault("node_capacity", 8 << 20)
    kw.setdefault("page_size", 1 << 14)
    kw.setdefault("replication_factor", 1)
    kw.setdefault("admission", True)
    if tmp_path is not None:
        kw.setdefault("spill_dir", os.path.join(str(tmp_path), "spill"))
    if backend == "proc":
        return Cluster(4, backend="proc", **kw)
    return Cluster(4, **kw)


def _teardown(cluster, backend):
    if backend == "proc":
        report = cluster.close()
        assert report.ok, report
    else:
        cluster.shutdown()


def _assert_clean(cluster):
    """No leaked reservations on any alive node (nor the driver)."""
    for nid, rep in cluster.pressure_report().items():
        assert rep["reserved"] == 0, (nid, rep)


def _tier(cluster, **kw):
    kw.setdefault("hbm_pages_per_node", 4)
    kw.setdefault("host_budget_bytes", 2048)
    return ServingTier(cluster, **kw)


# -- admission + diversion (tentpole) -----------------------------------------
def test_prefill_diverted_off_pressured_affinity_node(tmp_path):
    cluster = _cluster("inproc", tmp_path, node_capacity=1 << 20,
                       pressure_watermark=0.5)
    tier = _tier(cluster)
    seq = 11
    affinity = tier._affinity(seq)
    # ballast the affinity node past its watermark so the speculative
    # low-urgency probe AND the placement probe both refuse
    mm = cluster.nodes[affinity].memory
    ballast = mm.reserve(int(0.9 * (1 << 20)))
    mm.note_alloc(600 << 10)
    plan = tier.admit({seq: 8})
    assert plan.placement[seq] != affinity
    assert plan.diversions[seq][0] == affinity
    assert tier.stats["prefill_refusals"] == 1
    assert tier.verify(seq)
    ballast.release()
    mm.note_free(600 << 10)
    tier.close()
    _assert_clean(cluster)
    _teardown(cluster, "inproc")


def test_always_grant_baseline_never_diverts(tmp_path):
    cluster = _cluster("inproc", tmp_path, admission=False,
                       node_capacity=1 << 20)
    tier = _tier(cluster)
    plan = tier.admit({i: 8 for i in range(6)})
    assert plan.diversions == {}
    for i in range(6):
        assert plan.placement[i] == tier._affinity(i)
    tier.decode(list(range(6)), steps=4)
    assert all(tier.verify(i) for i in range(6))
    tier.close()
    _teardown(cluster, "inproc")


# -- three-level spill (tentpole) ---------------------------------------------
def test_three_level_spill_round_trips_byte_identically(tmp_path):
    """A sequence bigger than HBM with a tiny host budget pushes slabs
    through all three levels; reading the whole sequence back (block_table
    restore) faults them home byte-identically."""
    cluster = _cluster("inproc", tmp_path)
    tier = _tier(cluster, hbm_pages_per_node=3, host_budget_bytes=1024)
    tier.admit({7: 20})           # 5 pages > 3 HBM slots
    tier.decode([7], steps=12)    # 32 tokens = 8 pages
    shard = tier._shards[tier.sessions[7].node]
    assert shard.store.stats["host_puts"] > 0          # level 2 hit
    assert shard.store.stats["remote_spills"] > 0      # level 3 hit
    table = tier.block_table(7)   # restores every page for the kernel
    assert (table >= 0).all()
    assert shard.store.stats["remote_fetches"] > 0     # level 3 faulted back
    assert tier.verify(7)
    tier.close()
    _assert_clean(cluster)
    _teardown(cluster, "inproc")


def test_host_slabs_charge_the_nodes_memory_manager(tmp_path):
    cluster = _cluster("inproc", tmp_path)
    tier = ServingTier(cluster, hbm_pages_per_node=2,
                       host_budget_bytes=None)   # level 2 only, uncapped
    tier.admit({3: 16})
    node = tier.sessions[3].node
    assert cluster.nodes[node].memory.reserved_bytes > 0   # slabs charged
    tier.finish(3)
    _assert_clean(cluster)                                  # and released
    tier.close()
    _teardown(cluster, "inproc")


# -- fault-injection sweep (satellite 1) --------------------------------------
PHASES = ("after_admit", "mid_decode", "during_restore", "during_spill")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("phase", PHASES)
def test_kill_at_phase_boundary_resumes_byte_identically(
        tmp_path, backend, phase):
    """kill_node/SIGKILL at each serving phase boundary: the session must
    resume on its replica with byte-identical block-table contents, and no
    reservation may leak on any surviving node."""
    cluster = _cluster(backend, tmp_path)
    # budget 0 forces every eviction to level 3 so restore/spill phases fire
    tier = _tier(cluster, hbm_pages_per_node=3,
                 host_budget_bytes=0 if phase in ("during_restore",
                                                  "during_spill") else 1024)
    seqs = {1: 10, 2: 6}
    if phase == "after_admit":
        # the hook fires inside the prefill of the first admitted sequence
        tier.add_fault_hook(
            "after_admit",
            lambda: cluster.kill_node(tier.sessions[1].node))
        tier.admit(seqs)
    else:
        tier.admit(seqs)
        tier.decode([1, 2], steps=4)
        tier.add_fault_hook(
            phase, lambda: cluster.kill_node(tier.sessions[1].node))
    pre = {s: [x.copy() for x in tier.sequence_slabs(s)] for s in seqs}
    pre_len = {s: tier.sessions[s].length for s in seqs}
    if phase == "during_restore":
        # a whole-sequence read faults level-3 slabs home: the hook fires
        # inside the restore itself (spilled state settled first so the
        # restore genuinely comes from the remote tier)
        cluster.transfer.drain(timeout=10.0)
        tier._shards[tier.sessions[1].node].store._reap()
        tier.block_table(1)
    tier.decode([1, 2], steps=6)
    if phase != "after_admit":
        assert tier.stats["failovers"] >= 1, tier.stats
    for s in seqs:
        assert tier.verify(s), f"seq {s} diverged after {phase} kill"
        # committed pre-kill prefix is byte-identical on the new home
        now = tier.sequence_slabs(s)
        full = pre_len[s] // tier.page_tokens   # pages full before the kill
        for k in range(full):
            assert now[k].tobytes() == pre[s][k].tobytes()
        assert (tier.block_table(s) >= 0).all()
    for s in seqs:
        tier.finish(s)
    _assert_clean(cluster)
    tier.close()
    _teardown(cluster, backend)


@pytest.mark.parametrize("backend", BACKENDS)
def test_sigkill_mid_decode_without_replica_demands_rerun(tmp_path, backend):
    """The shuffle contract, honored verbatim: a dead serving node with no
    live replica raises DeadNodeError demanding a re-run."""
    cluster = _cluster(backend, tmp_path, replication_factor=0)
    tier = _tier(cluster, replicate=False)
    tier.admit({5: 8})
    tier.decode([5], steps=2)
    cluster.kill_node(tier.sessions[5].node)
    with pytest.raises(DeadNodeError, match="re-run"):
        tier.decode([5], steps=1)
    tier.close()
    _teardown(cluster, backend)


def test_spill_target_death_mid_transfer_loses_nothing(tmp_path):
    """Killing the level-3 spill *target* while a slab transfer is in
    flight must not lose the slab: the host copy is only dropped after the
    transfer confirms."""
    cluster = _cluster("inproc", tmp_path)
    tier = _tier(cluster, hbm_pages_per_node=3, host_budget_bytes=0)
    tier.admit({9: 10})
    node = tier.sessions[9].node
    target = tier._spill_target(node)
    tier.add_fault_hook("during_spill", lambda: cluster.kill_node(target))
    tier.decode([9], steps=8)
    store = tier._shards[tier.sessions[9].node].store
    cluster.transfer.drain(timeout=10.0)
    store._reap()
    assert tier.verify(9)    # every slab still reachable, byte-identical
    tier.close()
    _assert_clean(cluster)
    _teardown(cluster, "inproc")


def test_replica_death_repicks_and_survives_primary_death_later(tmp_path):
    cluster = _cluster("inproc", tmp_path)
    tier = _tier(cluster)
    tier.admit({4: 8})
    tier.decode([4], steps=2)
    sess = tier.sessions[4]
    cluster.kill_node(sess.replica)          # replica dies first
    tier.decode([4], steps=2)                # re-picks + re-ships
    assert sess.replica is not None and tier._alive(sess.replica)
    cluster.kill_node(sess.node)             # then the primary
    tier.decode([4], steps=2)
    assert tier.stats["failovers"] >= 1
    assert tier.verify(4)
    tier.close()
    _assert_clean(cluster)
    _teardown(cluster, "inproc")


# -- attention over the serving pool ------------------------------------------
def test_attend_runs_kernel_and_xla_identically_after_failover(tmp_path):
    cluster = _cluster("inproc", tmp_path)
    tier = _tier(cluster)
    tier.admit({1: 6, 2: 9})
    tier.decode([1, 2], steps=3)
    cluster.kill_node(tier.sessions[1].node)
    tier.decode([1, 2], steps=2)
    xla = tier.attend([1, 2], impl="xla")
    ker = tier.attend([1, 2], impl="kernel")
    for s in (1, 2):
        np.testing.assert_allclose(xla[s], ker[s], rtol=2e-5, atol=2e-5)
    tier.close()
    _teardown(cluster, "inproc")


# -- property: random op interleavings vs unlimited-HBM reference (satellite) -
_OPS = st.lists(
    st.tuples(st.integers(min_value=0, max_value=3),    # action
              st.integers(min_value=0, max_value=2),    # session slot
              st.integers(min_value=1, max_value=6)),   # tokens / steps
    min_size=4, max_size=24)


def _ref_extend(tier, ref, sid, old_len, new_len):
    """Mirror a tier prefill/decode into the reference cache."""
    ref.ensure_capacity(sid, new_len - old_len)
    ref.advance(sid, new_len - old_len)
    first = old_len // tier.page_tokens     # tail page may be rewritten
    for k in range(first, -(-new_len // tier.page_tokens)):
        ref.write_page(sid, k, tier._expected_slab(sid, k, new_len))


def _assert_matches_ref(tier, ref, sid):
    assert tier.sessions[sid].length == ref.seq_length(sid)
    mine = tier.sequence_slabs(sid)
    theirs = ref.sequence_slabs(sid)
    assert len(mine) == len(theirs)
    for k, (a, b) in enumerate(zip(mine, theirs)):
        assert a.tobytes() == b.tobytes(), (sid, k)


@settings(max_examples=10, deadline=None)
@given(ops=_OPS)
def test_random_interleavings_match_unlimited_hbm_reference(ops):
    """Any interleaving of admit/decode/read/finish over the spilling tier
    (3 HBM slots, 512-byte host budget => all three spill levels exercised)
    stays byte-identical to a reference PagedKVCache with unlimited HBM that
    never evicts, spills, or restores."""
    cluster = Cluster(3, node_capacity=8 << 20, page_size=1 << 14,
                      replication_factor=1, admission=True)
    tier = ServingTier(cluster, hbm_pages_per_node=3, host_budget_bytes=512)
    ref = PagedKVCache(num_layers=tier.num_layers, hbm_pages=512,
                       page_size=tier.page_tokens, kv_heads=tier.kv_heads,
                       head_dim=tier.head_dim)
    try:
        lengths = {}
        for action, slot, n in ops:
            sid = 100 + slot
            if action == 0 and sid not in tier.sessions:
                tier.admit({sid: n})
                ref.start_sequence(sid)
                _ref_extend(tier, ref, sid, 0, n)
                lengths[sid] = n
            elif action == 1 and sid in lengths:
                tier.decode([sid], steps=n)
                _ref_extend(tier, ref, sid, lengths[sid], lengths[sid] + n)
                lengths[sid] += n
            elif action == 2 and sid in lengths:
                assert tier.verify(sid)
                assert (tier.block_table(sid) >= 0).all()
                _assert_matches_ref(tier, ref, sid)
            elif action == 3 and sid in lengths:
                tier.finish(sid)
                ref.finish_sequence(sid)
                del lengths[sid]
        for sid in list(lengths):
            _assert_matches_ref(tier, ref, sid)
    finally:
        tier.close()
    _assert_clean(cluster)
    _teardown(cluster, "inproc")


# -- oracle sanity ------------------------------------------------------------
def test_expected_page_slab_is_deterministic_and_masked():
    a = expected_page_slab(3, 1, 6, num_layers=2, page_tokens=4,
                           kv_heads=2, head_dim=4)
    b = expected_page_slab(3, 1, 6, num_layers=2, page_tokens=4,
                           kv_heads=2, head_dim=4)
    assert a.tobytes() == b.tobytes()
    assert (a[:, 2:] == 0).all()      # positions 6,7 past the length
    assert (a[:, :2] != 0).all()
