"""Per-arch smoke tests (assignment f): each reduced-family config runs one
forward + one train step on CPU, asserting output shapes and no NaNs; decode
consistency for representative families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, smoke_config
from repro.models.model import (active_params, build_model, count_params,
                                input_specs)
from repro.configs.base import TRAIN_4K, shapes_for, LONG_500K
from repro.configs import get_config
from repro.optim import make_train_step
from repro.optim.train_state import make_train_state

RNG = np.random.default_rng(0)
KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, T=16):
    if cfg.family == "encdec":
        return {"src_embeds": jnp.asarray(
                    RNG.normal(size=(B, T, cfg.d_model)), jnp.float32),
                "tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (B, T)),
                                      jnp.int32),
                "labels": jnp.asarray(RNG.integers(0, cfg.vocab, (B, T)),
                                      jnp.int32)}
    b = {"labels": jnp.asarray(RNG.integers(0, cfg.vocab, (B, T)),
                               jnp.int32)}
    if cfg.embed_inputs:
        b["embeds"] = jnp.asarray(RNG.normal(size=(B, T, cfg.d_model)),
                                  jnp.float32)
    else:
        b["tokens"] = jnp.asarray(RNG.integers(0, cfg.vocab, (B, T)),
                                  jnp.int32)
    if cfg.rope == "mrope":
        b["positions"] = jnp.broadcast_to(
            jnp.arange(T)[None, None, :], (B, 3, T)).astype(jnp.int32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # one SGD-ish train step: loss finite, params change, no NaNs
    state = make_train_state(params, cfg.opt_state_dtype)
    step = make_train_step(model.loss, lr=1e-3)
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    leaves1 = jax.tree.leaves(state.params)
    leaves2 = jax.tree.leaves(state2.params)
    changed = any(not np.array_equal(a, b) for a, b in zip(leaves1, leaves2))
    assert changed
    assert all(np.isfinite(np.asarray(l, np.float32)).all()
               for l in leaves2)


@pytest.mark.parametrize("arch", ["glm4-9b", "deepseek-v2-lite-16b",
                                  "rwkv6-3b", "recurrentgemma-9b"])
def test_decode_matches_full_forward(arch):
    cfg = smoke_config(arch).with_(compute_dtype="float32",
                                   kv_cache_dtype="float32",
                                   capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, T0, T = 2, 10, 14
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, T)), jnp.int32)
    full_logits, _ = model.forward(params, {"tokens": toks})
    pre_logits, cache = model.prefill(params, {"tokens": toks[:, :T0]},
                                      max_len=T)
    np.testing.assert_allclose(pre_logits, full_logits[:, :T0],
                               rtol=1e-4, atol=1e-4)
    for t in range(T0, T):
        lg, cache = model.decode_step(params, {"tokens": toks[:, t:t + 1]},
                                      cache, t)
        np.testing.assert_allclose(lg[:, 0], full_logits[:, t],
                                   rtol=1e-4, atol=1e-4)


def test_moe_aux_loss_nonzero():
    cfg = smoke_config("deepseek-v2-lite-16b")
    model = build_model(cfg)
    params = model.init(KEY)
    _, aux = model.forward(params, _batch(cfg))
    assert float(aux) > 0


def test_long_500k_only_for_subquadratic():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        names = [s.name for s in shapes_for(cfg)]
        if arch in ("rwkv6-3b", "recurrentgemma-9b"):
            assert LONG_500K.name in names
        else:
            assert LONG_500K.name not in names


def test_param_counts_match_published_scale():
    """Full configs land near their published parameter counts."""
    expect = {
        "grok-1-314b": (280e9, 345e9),
        "deepseek-v2-lite-16b": (14e9, 18e9),
        "glm4-9b": (8e9, 10.5e9),
        "olmo-1b": (0.9e9, 1.4e9),
        "qwen3-0.6b": (0.55e9, 0.85e9),
        "minitron-8b": (7e9, 10.2e9),   # untied embeddings add ~1B
        "rwkv6-3b": (2.5e9, 3.8e9),
        "recurrentgemma-9b": (7.5e9, 12e9),
        "qwen2-vl-72b": (65e9, 78e9),
        "seamless-m4t-large-v2": (1.4e9, 2.8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_active_params_moe():
    cfg = get_config("deepseek-v2-lite-16b")
    total, act = count_params(cfg), active_params(cfg)
    assert act < total * 0.35  # top-6 of 64 routed → far fewer active


def test_input_specs_cover_all_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            model = build_model(cfg)
            specs = input_specs(cfg, shape, model=model)
            assert "batch" in specs
            leaves = jax.tree.leaves(specs)
            assert all(hasattr(l, "shape") for l in leaves)
