"""HLO analyzer: trip-count scaling and collective accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo, parse_hlo


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_scale_with_scan_trip_count():
    W1 = jax.ShapeDtypeStruct((1, 128, 128), jnp.float32)
    W10 = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    X = jax.ShapeDtypeStruct((32, 128), jnp.float32)

    def f(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    s1 = analyze_hlo(_compile_text(f, W1, X))
    s10 = analyze_hlo(_compile_text(f, W10, X))
    expected_one = 2 * 32 * 128 * 128
    assert abs(s1.dot_flops - expected_one) / expected_one < 0.01
    assert abs(s10.dot_flops - 10 * expected_one) / (10 * expected_one) < 0.01


def test_nested_scan_trip_counts_multiply():
    W = jax.ShapeDtypeStruct((3, 4, 64, 64), jnp.float32)
    X = jax.ShapeDtypeStruct((16, 64), jnp.float32)

    def f(w, x):
        def outer(c, wo):
            def inner(ci, wi):
                return jnp.tanh(ci @ wi), None
            c2, _ = jax.lax.scan(inner, c, wo)
            return c2, None
        y, _ = jax.lax.scan(outer, x, w)
        return y.sum()

    s = analyze_hlo(_compile_text(f, W, X))
    expected = 12 * 2 * 16 * 64 * 64
    assert abs(s.dot_flops - expected) / expected < 0.01


def test_parse_computations_and_entry():
    X = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    txt = _compile_text(lambda x: (x @ x).sum(), X)
    comps, entry = parse_hlo(txt)
    assert entry is not None and entry in comps
    total_dots = sum(1 for c in comps.values()
                     for i in c.instrs if i.opcode == "dot")
    assert total_dots == 1
