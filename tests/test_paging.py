"""Data-aware paging: Eq. 1 priority + Alg. 1 victim selection (paper §6)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (AttributeSet, BufferPool, CurrentOperation,
                        DurabilityType, EvictionStrategy, Lifetime,
                        PoolExhaustedError, ReadingPattern, WritingPattern,
                        eviction_overhead, select_strategy, spilling_cost)
from repro.core.locality_set import LocalitySet
from repro.core.paging import PagingSystem


def _set(name, writing=WritingPattern.SEQUENTIAL_WRITE,
         reading=ReadingPattern.NONE,
         durability=DurabilityType.WRITE_BACK):
    return LocalitySet(name, 1024, AttributeSet(
        durability=durability, writing=writing, reading=reading))


def test_table3_spilling_costs():
    assert spilling_cost(WritingPattern.SEQUENTIAL_WRITE,
                         ReadingPattern.SEQUENTIAL_READ,
                         DurabilityType.WRITE_THROUGH) == 1.0
    assert spilling_cost(WritingPattern.SEQUENTIAL_WRITE,
                         ReadingPattern.SEQUENTIAL_READ,
                         DurabilityType.WRITE_BACK) == 2.5
    assert spilling_cost(WritingPattern.CONCURRENT_WRITE,
                         ReadingPattern.NONE,
                         DurabilityType.WRITE_BACK) == 2.5
    assert spilling_cost(WritingPattern.RANDOM_MUTABLE_WRITE,
                         ReadingPattern.RANDOM_READ,
                         DurabilityType.WRITE_BACK) == 5.0


def test_strategy_selection_rule():
    """MRU for sequential/concurrent patterns, LRU for random (paper §6)."""
    assert select_strategy(WritingPattern.SEQUENTIAL_WRITE,
                           ReadingPattern.NONE) == EvictionStrategy.MRU
    assert select_strategy(WritingPattern.CONCURRENT_WRITE,
                           ReadingPattern.NONE) == EvictionStrategy.MRU
    assert select_strategy(WritingPattern.NONE,
                           ReadingPattern.SEQUENTIAL_READ) == EvictionStrategy.MRU
    assert select_strategy(WritingPattern.RANDOM_MUTABLE_WRITE,
                           ReadingPattern.NONE) == EvictionStrategy.LRU
    assert select_strategy(WritingPattern.NONE,
                           ReadingPattern.RANDOM_READ) == EvictionStrategy.LRU


def test_eq1_lifetime_ended_preferred():
    """Lifetime-ended sets have negative overhead → always evicted first."""
    ps = PagingSystem()
    alive = _set("alive")
    ended = _set("ended")
    ps.register(alive, clock=10)
    ps.register(ended, clock=10)
    alive._touch(50)
    ended.end_lifetime(40)
    order = ps.priority_order(clock=100)
    assert order[0][0] == "ended" and order[0][1] < 0


def test_eq1_recency_orders_alive_sets():
    """Same cost: the colder (older t_r) set is the better victim."""
    ps = PagingSystem()
    hot, cold = _set("hot"), _set("cold")
    ps.register(hot, 1)
    ps.register(cold, 1)
    cold._touch(10)
    hot._touch(90)
    order = ps.priority_order(clock=100)
    assert [n for n, _ in order] == ["cold", "hot"]


def test_eq1_cost_orders_alive_sets():
    """Same recency: cheaper-to-spill (write-through seq) evicted first."""
    ps = PagingSystem()
    cheap = _set("cheap", durability=DurabilityType.WRITE_THROUGH)
    costly = _set("costly", writing=WritingPattern.RANDOM_MUTABLE_WRITE,
                  reading=ReadingPattern.RANDOM_READ)
    ps.register(cheap, 1)
    ps.register(costly, 1)
    cheap._touch(50)
    costly._touch(50)
    order = ps.priority_order(clock=100)
    assert [n for n, _ in order] == ["cheap", "costly"]


def test_eviction_ratio_limits_writing_sets():
    pool = BufferPool(64 * 1024)
    ls = pool.create_set("w", 1024)
    ls.attrs.writing = WritingPattern.SEQUENTIAL_WRITE
    ls.set_operation(CurrentOperation.WRITE, pool.clock)
    pages = [pool.new_page(ls) for _ in range(20)]
    for p in pages:
        pool.unpin(p, dirty=True)
    victims = ls.select_victims()
    assert len(victims) == 2  # 10% of 20
    ls.set_operation(CurrentOperation.READ, pool.clock)
    assert len(ls.select_victims()) == 20  # no limit while reading


def test_mru_vs_lru_victim_order():
    pool = BufferPool(64 * 1024)
    seq = pool.create_set("seq", 1024)
    seq.infer_from_service("sequential-write", pool.clock)
    pages = [pool.new_page(seq) for _ in range(4)]
    for p in pages:
        pool.unpin(p, dirty=True)
    seq.set_operation(CurrentOperation.READ, pool.clock)
    victims = seq.select_victims()
    # MRU: most recently allocated first
    assert victims[0].page_id == pages[-1].page_id

    rnd = pool.create_set("rnd", 1024)
    rnd.infer_from_service("hash", pool.clock)
    rpages = [pool.new_page(rnd) for _ in range(4)]
    for p in rpages:
        pool.unpin(p)
    rnd.set_operation(CurrentOperation.READ, pool.clock)
    victims = rnd.select_victims()
    assert victims[0].page_id == rpages[0].page_id  # LRU: oldest first


def test_pinned_pages_never_evicted():
    pool = BufferPool(8 * 1024)
    ls = pool.create_set("a", 1024)
    pinned = pool.new_page(ls)          # stays pinned
    rest = [pool.new_page(ls) for _ in range(6)]
    for p in rest:
        pool.unpin(p, dirty=True)
    # allocate more than remaining capacity: must evict unpinned only
    ls2 = pool.create_set("b", 1024)
    for _ in range(10):
        pool.unpin(pool.new_page(ls2), dirty=True)
    assert pinned.resident and pinned.pinned


def test_pool_exhausted_when_all_pinned():
    pool = BufferPool(4 * 1024)
    ls = pool.create_set("a", 1024)
    pages = [pool.new_page(ls) for _ in range(3)]  # pinned
    with pytest.raises(PoolExhaustedError):
        for _ in range(5):
            pool.new_page(ls)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 64), st.integers(2, 64))
def test_eq1_overhead_monotone_in_recency(t1, t2):
    """For alive sets with equal cost, overhead is increasing in t_r —
    more recently used ⇒ more expensive to evict (kept longer)."""
    a, b = _set("a"), _set("b")
    a.attrs.access_recency = min(t1, t2)
    b.attrs.access_recency = max(t1, t2)
    clock = 100
    assert eviction_overhead(a, clock) <= eviction_overhead(b, clock)
