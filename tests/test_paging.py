"""Data-aware paging: Eq. 1 priority + Alg. 1 victim selection (paper §6)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (AttributeSet, BufferPool, CurrentOperation,
                        DurabilityType, EvictionStrategy, Lifetime,
                        PoolExhaustedError, ReadingPattern, WritingPattern,
                        eviction_overhead, select_strategy, spilling_cost)
from repro.core.locality_set import LocalitySet
from repro.core.paging import PagingSystem


def _set(name, writing=WritingPattern.SEQUENTIAL_WRITE,
         reading=ReadingPattern.NONE,
         durability=DurabilityType.WRITE_BACK):
    return LocalitySet(name, 1024, AttributeSet(
        durability=durability, writing=writing, reading=reading))


def test_table3_spilling_costs():
    assert spilling_cost(WritingPattern.SEQUENTIAL_WRITE,
                         ReadingPattern.SEQUENTIAL_READ,
                         DurabilityType.WRITE_THROUGH) == 1.0
    assert spilling_cost(WritingPattern.SEQUENTIAL_WRITE,
                         ReadingPattern.SEQUENTIAL_READ,
                         DurabilityType.WRITE_BACK) == 2.5
    assert spilling_cost(WritingPattern.CONCURRENT_WRITE,
                         ReadingPattern.NONE,
                         DurabilityType.WRITE_BACK) == 2.5
    assert spilling_cost(WritingPattern.RANDOM_MUTABLE_WRITE,
                         ReadingPattern.RANDOM_READ,
                         DurabilityType.WRITE_BACK) == 5.0


def test_strategy_selection_rule():
    """MRU for sequential/concurrent patterns, LRU for random (paper §6)."""
    assert select_strategy(WritingPattern.SEQUENTIAL_WRITE,
                           ReadingPattern.NONE) == EvictionStrategy.MRU
    assert select_strategy(WritingPattern.CONCURRENT_WRITE,
                           ReadingPattern.NONE) == EvictionStrategy.MRU
    assert select_strategy(WritingPattern.NONE,
                           ReadingPattern.SEQUENTIAL_READ) == EvictionStrategy.MRU
    assert select_strategy(WritingPattern.RANDOM_MUTABLE_WRITE,
                           ReadingPattern.NONE) == EvictionStrategy.LRU
    assert select_strategy(WritingPattern.NONE,
                           ReadingPattern.RANDOM_READ) == EvictionStrategy.LRU


def test_eq1_lifetime_ended_preferred():
    """Lifetime-ended sets have negative overhead → always evicted first."""
    ps = PagingSystem()
    alive = _set("alive")
    ended = _set("ended")
    ps.register(alive, clock=10)
    ps.register(ended, clock=10)
    alive._touch(50)
    ended.end_lifetime(40)
    order = ps.priority_order(clock=100)
    assert order[0][0] == "ended" and order[0][1] < 0


def test_eq1_recency_orders_alive_sets():
    """Same cost: the colder (older t_r) set is the better victim."""
    ps = PagingSystem()
    hot, cold = _set("hot"), _set("cold")
    ps.register(hot, 1)
    ps.register(cold, 1)
    cold._touch(10)
    hot._touch(90)
    order = ps.priority_order(clock=100)
    assert [n for n, _ in order] == ["cold", "hot"]


def test_eq1_cost_orders_alive_sets():
    """Same recency: cheaper-to-spill (write-through seq) evicted first."""
    ps = PagingSystem()
    cheap = _set("cheap", durability=DurabilityType.WRITE_THROUGH)
    costly = _set("costly", writing=WritingPattern.RANDOM_MUTABLE_WRITE,
                  reading=ReadingPattern.RANDOM_READ)
    ps.register(cheap, 1)
    ps.register(costly, 1)
    cheap._touch(50)
    costly._touch(50)
    order = ps.priority_order(clock=100)
    assert [n for n, _ in order] == ["cheap", "costly"]


def test_eviction_ratio_limits_writing_sets():
    pool = BufferPool(64 * 1024)
    ls = pool.create_set("w", 1024)
    ls.attrs.writing = WritingPattern.SEQUENTIAL_WRITE
    ls.set_operation(CurrentOperation.WRITE, pool.clock)
    pages = [pool.new_page(ls) for _ in range(20)]
    for p in pages:
        pool.unpin(p, dirty=True)
    victims = ls.select_victims()
    assert len(victims) == 2  # 10% of 20
    ls.set_operation(CurrentOperation.READ, pool.clock)
    assert len(ls.select_victims()) == 20  # no limit while reading


def test_mru_vs_lru_victim_order():
    pool = BufferPool(64 * 1024)
    seq = pool.create_set("seq", 1024)
    seq.infer_from_service("sequential-write", pool.clock)
    pages = [pool.new_page(seq) for _ in range(4)]
    for p in pages:
        pool.unpin(p, dirty=True)
    seq.set_operation(CurrentOperation.READ, pool.clock)
    victims = seq.select_victims()
    # MRU: most recently allocated first
    assert victims[0].page_id == pages[-1].page_id

    rnd = pool.create_set("rnd", 1024)
    rnd.infer_from_service("hash", pool.clock)
    rpages = [pool.new_page(rnd) for _ in range(4)]
    for p in rpages:
        pool.unpin(p)
    rnd.set_operation(CurrentOperation.READ, pool.clock)
    victims = rnd.select_victims()
    assert victims[0].page_id == rpages[0].page_id  # LRU: oldest first


def test_pinned_pages_never_evicted():
    pool = BufferPool(8 * 1024)
    ls = pool.create_set("a", 1024)
    pinned = pool.new_page(ls)          # stays pinned
    rest = [pool.new_page(ls) for _ in range(6)]
    for p in rest:
        pool.unpin(p, dirty=True)
    # allocate more than remaining capacity: must evict unpinned only
    ls2 = pool.create_set("b", 1024)
    for _ in range(10):
        pool.unpin(pool.new_page(ls2), dirty=True)
    assert pinned.resident and pinned.pinned


def test_pool_exhausted_when_all_pinned():
    pool = BufferPool(4 * 1024)
    ls = pool.create_set("a", 1024)
    pages = [pool.new_page(ls) for _ in range(3)]  # pinned
    with pytest.raises(PoolExhaustedError):
        for _ in range(5):
            pool.new_page(ls)


def test_eq1_ended_always_below_alive_any_recency():
    """Eq. 1 edge case: a lifetime-ended set sorts below EVERY alive set, no
    matter how stale the alive set or how fresh the ended one — ended data is
    worthless by definition, alive data never is."""
    ps = PagingSystem()
    stale_alive = _set("stale_alive")
    fresh_ended = _set("fresh_ended")
    ps.register(stale_alive, 1)
    ps.register(fresh_ended, 1)
    stale_alive._touch(2)            # touched ages ago
    fresh_ended._touch(99)
    fresh_ended.end_lifetime(99)     # ended just now
    order = ps.priority_order(clock=100)
    assert [n for n, _ in order] == ["fresh_ended", "stale_alive"]
    assert order[0][1] < 0 < order[1][1]


def test_eq1_older_ended_set_evicted_first():
    """Among ended sets, O = -t_now/t_r: the LONGER a set has been dead, the
    more negative its overhead, so the stalest corpse goes first."""
    ps = PagingSystem()
    old, recent = _set("old"), _set("recent")
    ps.register(old, 1)
    ps.register(recent, 1)
    old.end_lifetime(10)
    recent.end_lifetime(90)
    order = ps.priority_order(clock=100)
    assert [n for n, _ in order] == ["old", "recent"]


def test_eq1_recency_tie_same_overhead():
    """Equal recency AND equal cost => identical overhead; neither set is
    preferred by Eq. 1 itself (the heap's insertion order breaks the tie)."""
    ps = PagingSystem()
    a, b = _set("a"), _set("b")
    ps.register(a, 1)
    ps.register(b, 1)
    a._touch(40)
    b._touch(40)
    order = ps.priority_order(clock=100)
    assert order[0][1] == order[1][1]
    ended_a, ended_b = _set("ea"), _set("eb")
    ended_a.end_lifetime(40)
    ended_b.end_lifetime(40)
    assert eviction_overhead(ended_a, 100) == eviction_overhead(ended_b, 100)


def test_write_eviction_cap_rounds_up_to_one():
    """The 10% cap under CurrentOperation=WRITE always yields >= 1 victim —
    a writing set with few pages must still be evictable (no livelock)."""
    pool = BufferPool(64 * 1024)
    ls = pool.create_set("w", 1024)
    ls.infer_from_service("sequential-write", pool.clock)
    pages = [pool.new_page(ls) for _ in range(5)]
    for p in pages:
        pool.unpin(p, dirty=True)
    ls.set_operation(CurrentOperation.WRITE, pool.clock)
    assert len(ls.select_victims()) == 1  # int(5 * 0.1) == 0, but capped up


def test_write_eviction_cap_under_allocation_pressure():
    """End to end through Alg. 1: while a CurrentOperation-writing set is the
    victim, each eviction decision only reclaims pages incrementally (10% of
    candidates per pick), and the writer still completes once eviction frees
    room — the cap throttles, it must not deadlock."""
    pool = BufferPool(32 * 1024)
    ls = pool.create_set("w", 1024)
    ls.infer_from_service("sequential-write", pool.clock)
    held = []
    for _ in range(64):  # 2x the pool; forces repeated eviction while WRITE
        page = pool.new_page(ls)
        pool.unpin(page, dirty=True)
        held.append(page)
    assert ls.attrs.operation == CurrentOperation.WRITE
    assert pool.stats["evictions"] > 0
    resident = sum(1 for p in held if p.resident)
    assert resident <= 32  # never exceeds capacity
    # every eviction decision respected the cap at decision time
    victims = ls.select_victims()
    unpinned = len(ls.unpinned_resident_pages())
    assert len(victims) == max(1, int(unpinned * 0.10))


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 64), st.integers(2, 64))
def test_eq1_overhead_monotone_in_recency(t1, t2):
    """For alive sets with equal cost, overhead is increasing in t_r —
    more recently used ⇒ more expensive to evict (kept longer)."""
    a, b = _set("a"), _set("b")
    a.attrs.access_recency = min(t1, t2)
    b.attrs.access_recency = max(t1, t2)
    clock = 100
    assert eviction_overhead(a, clock) <= eviction_overhead(b, clock)
