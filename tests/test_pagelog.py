"""Durable per-node page tier (PR 6): append-only page log + consistent-hash
index unit behaviour, torn-tail truncation, warm-vs-cold cluster restarts,
recovery-plan disk-vs-wire costing, and the revival epoch fence (the
kill+revive carried bugfix: stale log state must not resurrect)."""
import os

import numpy as np
import pytest

from repro.core.pagelog import (LOG_FILENAME, ConsistentHashIndex, PageLog,
                                fsck)
from repro.runtime.cluster import Cluster

PAIR = np.dtype([("key", np.int64), ("val", np.float64)])


def _pairs(n, key_range, seed=0):
    rng = np.random.default_rng(seed)
    recs = np.zeros(n, PAIR)
    recs["key"] = rng.integers(0, key_range, n)
    recs["val"] = rng.random(n)
    return recs


def _sorted(recs):
    return np.sort(recs, order=["key", "val"])


def _cluster(tmp_path, replication_factor=1, **kw):
    kw.setdefault("node_capacity", 16 << 20)
    kw.setdefault("page_size", 1 << 16)
    kw.setdefault("pagelog_dir", str(tmp_path / "pagelog"))
    return Cluster(4, replication_factor=replication_factor, **kw)


# -- page log unit behaviour --------------------------------------------------
def test_append_read_roundtrip_and_supersede(tmp_path):
    log = PageLog(str(tmp_path))
    a0 = os.urandom(512)
    a1 = os.urandom(512)
    log.append("a", a0)                    # seq 0 allocated
    log.append("a", a1)                    # seq 1
    assert log.read("a", 0) == a0
    assert log.read("a", 1) == a1
    assert log.next_seq("a") == 2
    # re-appending an existing seq supersedes in place: index keeps newest
    a0b = os.urandom(512)
    log.append("a", a0b, seq=0)
    assert log.read("a", 0) == a0b
    assert len(log.entries_for("a")) == 2  # still two live pages
    assert log.set_bytes("a") == 1024
    log.close()


def test_replay_rebuilds_index_with_tombstones_and_renames(tmp_path):
    log = PageLog(str(tmp_path))
    pages = [os.urandom(256) for _ in range(3)]
    for p in pages:
        log.append("keep", p)
    log.append("gone", os.urandom(256))
    log.drop_set("gone")                   # tombstone
    log.rename_set("keep", "kept")         # O(1) re-key, no data rewrite
    log.close()

    warm = PageLog(str(tmp_path))          # construction IS the replay
    assert warm.set_names() == ["kept"]
    assert [warm.read("kept", i) for i in range(3)] == pages
    assert warm.next_seq("kept") == 3      # seq allocation survives restart
    assert warm.report["tombstones"] == 1
    assert warm.report["renames"] == 1
    assert warm.report["truncated_bytes"] == 0
    warm.close()


def test_torn_tail_truncated_on_replay(tmp_path):
    log = PageLog(str(tmp_path))
    keep = [os.urandom(300), os.urandom(300)]
    log.append("t", keep[0])
    log.append("t", keep[1])
    log.append("t", os.urandom(300))       # this record will be torn
    log.close()
    path = os.path.join(str(tmp_path), LOG_FILENAME)
    with open(path, "r+b") as f:           # crash mid-append: short tail
        f.truncate(os.path.getsize(path) - 5)

    rep = fsck(str(tmp_path))              # read-only check sees the tear
    assert not rep["clean"] and rep["torn_tail_bytes"] > 0

    warm = PageLog(str(tmp_path))          # replay cuts back to last good
    assert warm.report["truncated_bytes"] > 0
    assert [e.seq for e in warm.entries_for("t")] == [0, 1]
    assert [warm.read("t", i) for i in range(2)] == keep
    warm.close()
    post = fsck(str(tmp_path))             # the tear is gone from disk
    assert post["clean"] and post["torn_tail_bytes"] == 0
    assert post["records"] == 2


def test_index_keeps_one_set_in_one_bucket():
    """Set-granular ops touch one bucket: every page of a set hashes to the
    same ring interval regardless of seq."""
    idx = ConsistentHashIndex(num_buckets=8)
    from repro.core.pagelog import PageLogEntry
    for seq in range(20):
        idx.put(PageLogEntry(name="s", seq=seq, epoch=0, offset=0,
                             length=1, payload_crc=0))
    b = idx.bucket_of("s")
    assert all(("s", seq) in idx._buckets[b] for seq in range(20))
    assert [e.seq for e in idx.entries_for("s")] == list(range(20))
    assert idx.drop_set("s") == 20 and len(idx) == 0


# -- warm vs cold cluster restart ---------------------------------------------
def test_warm_restart_is_byte_identical_with_zero_net_bytes(tmp_path):
    cluster = _cluster(tmp_path)
    recs = _pairs(20_000, 1_500, seed=3)
    sset = cluster.create_sharded_set("t", recs, key_fn=lambda r: r["key"])
    expect = _sorted(cluster.read_sharded(sset))
    cluster.kill_node(2)
    base_net = cluster.net_bytes
    report = cluster.recover_node(2)
    assert report.ok, report.checksum_failures
    # the primary came off local disk, not the wire
    assert report.sources["t:2"] == "pagelog"
    assert report.warm_shards >= 1
    # the replica node 2 held for a peer warm-restored from the log too
    assert report.warm_replicas >= 1
    assert cluster.net_bytes == base_net
    assert np.array_equal(_sorted(cluster.read_sharded(sset)), expect)
    cluster.shutdown()


def test_cold_restart_pulls_replica_bytes(tmp_path):
    """The machine's disk died with it: wiping the log before revival forces
    the wire path, still byte-identical."""
    import shutil

    cluster = _cluster(tmp_path)
    recs = _pairs(20_000, 1_500, seed=4)
    sset = cluster.create_sharded_set("t", recs, key_fn=lambda r: r["key"])
    expect = _sorted(cluster.read_sharded(sset))
    cluster.kill_node(2)
    shutil.rmtree(cluster._node_pagelog_dir(2), ignore_errors=True)
    base_net = cluster.net_bytes
    report = cluster.recover_node(2)
    assert report.ok, report.checksum_failures
    assert report.sources["t:2"].startswith("replica@")
    assert report.warm_shards == 0
    assert cluster.net_bytes > base_net
    assert np.array_equal(_sorted(cluster.read_sharded(sset)), expect)
    cluster.shutdown()


# -- recovery costing: local disk vs wire -------------------------------------
def test_recovery_plan_flips_pagelog_vs_replica_as_disk_cost_rises(tmp_path):
    cluster = _cluster(tmp_path)
    recs = _pairs(16_000, 900, seed=5)
    sset = cluster.create_sharded_set("t", recs, key_fn=lambda r: r["key"])
    cluster.kill_node(2)
    cluster.revive_node(2)                 # warm: log replayed, pool empty
    plan = cluster.scheduler.recovery_plan(sset, 2, target_node=2)
    kinds = [s.kind for s in plan]
    assert kinds[0] == "pagelog"           # default: disk byte < wire byte
    assert "replica" in kinds
    log_src = plan[0]
    assert log_src.disk_bytes > 0 and log_src.cost_bytes == 0
    # flip the cost model: disk reads priced above wire pulls
    cluster.scheduler.disk_byte_cost = 1e6
    plan = cluster.scheduler.recovery_plan(sset, 2, target_node=2)
    assert plan[0].kind == "replica"
    assert plan[-1].kind == "pagelog"
    cluster.shutdown()


def test_recovery_plan_has_no_pagelog_source_without_durable_tier():
    cluster = Cluster(4, node_capacity=16 << 20, page_size=1 << 16,
                      replication_factor=1)
    recs = _pairs(8_000, 500, seed=6)
    sset = cluster.create_sharded_set("t", recs, key_fn=lambda r: r["key"])
    cluster.kill_node(1)
    cluster.revive_node(1)
    plan = cluster.scheduler.recovery_plan(sset, 1, target_node=1)
    assert all(s.kind != "pagelog" for s in plan)
    cluster.shutdown()


# -- revival fence (carried bugfix: kill+revive must not resurrect) ----------
def test_revive_fences_sets_dropped_while_dead(tmp_path):
    cluster = _cluster(tmp_path)
    keep = cluster.create_sharded_set("keep", _pairs(8_000, 500, seed=7),
                                      key_fn=lambda r: r["key"])
    tmp = cluster.create_sharded_set("tmp", _pairs(8_000, 500, seed=8),
                                     key_fn=lambda r: r["key"])
    cluster.kill_node(1)
    cluster.drop_sharded_set(tmp)          # dropped while node 1 was dead
    fenced = cluster.revive_node(1)
    # the dead node's log still held tmp's pages; the fence purged them
    assert fenced and all(n.startswith("tmp/") for n in fenced)
    log = cluster.nodes[1].pool.memory.pagelog
    assert not any(n.startswith("tmp/") for n in log.set_names())
    # keep's shard survived the fence and still warm-recovers
    plan = cluster.scheduler.recovery_plan(keep, 1, target_node=1)
    assert plan[0].kind == "pagelog"
    cluster.shutdown()


def test_stale_log_epoch_is_not_a_recovery_source(tmp_path):
    """A shard re-sharded/rebuilt elsewhere while its owner was dead carries
    a newer catalog epoch than the dead owner's log entries: the log must
    not be offered as a source for bytes it no longer truthfully holds."""
    cluster = _cluster(tmp_path)
    recs = _pairs(12_000, 700, seed=9)
    sset = cluster.create_sharded_set("t", recs, key_fn=lambda r: r["key"])
    cluster.kill_node(1)
    cluster.revive_node(1)
    # catalog stamped a newer epoch than anything node 1 ever logged
    sset.shards[1].epoch = cluster.stats.event_seq + 10
    plan = cluster.scheduler.recovery_plan(sset, 1, target_node=1)
    assert all(s.kind != "pagelog" for s in plan)
    cluster.shutdown()


def test_double_revive_raises(tmp_path):
    cluster = _cluster(tmp_path)
    cluster.create_sharded_set("t", _pairs(4_000, 300, seed=10),
                               key_fn=lambda r: r["key"])
    cluster.kill_node(3)
    cluster.revive_node(3)
    with pytest.raises(ValueError):
        cluster.revive_node(3)
    cluster.shutdown()


# -- overcommit: the pool degrades to the log instead of failing -------------
def test_scan_larger_than_pool_completes_through_the_log(tmp_path):
    recs = _pairs(30_000, 2_000, seed=11)
    # 2x data (primaries + factor-1 replicas) against pools that cannot
    # hold it: write-through pages overflow into the durable tier
    capacity = max(4 << 16, recs.nbytes // 8)
    cluster = Cluster(4, node_capacity=capacity, page_size=1 << 16,
                      replication_factor=1,
                      pagelog_dir=str(tmp_path / "pagelog"))
    sset = cluster.create_sharded_set("big", recs, key_fn=lambda r: r["key"])
    back = cluster.read_sharded(sset)
    assert np.array_equal(_sorted(back), _sorted(recs))
    log_bytes = sum(node.memory.stats["log_bytes"]
                    for node in cluster.nodes.values())
    assert log_bytes > 0
    cluster.shutdown()


# -- background compaction (PR 8 satellite) ----------------------------------
def test_compaction_rewrites_live_records_into_new_generation(tmp_path):
    log = PageLog(str(tmp_path))
    a_new = os.urandom(1024)
    log.append("a", os.urandom(1024))
    log.append("a", a_new, seq=0)          # supersede: old image is dead
    log.append("b", os.urandom(512))
    log.drop_set("b")                      # tombstoned: fully dead
    assert log.amplification() > 2.0
    before_entries = {name: [(e.seq, e.epoch) for e in log.entries_for(name)]
                      for name in log.set_names()}
    stats = log.compact()
    assert stats["generation"] == 1
    assert stats["records"] == 1
    assert stats["after_bytes"] < stats["before_bytes"]
    assert log.amplification() < 1.2
    # reads, seqs, and epochs are identical across the swap
    assert log.read("a", 0) == a_new
    assert {name: [(e.seq, e.epoch) for e in log.entries_for(name)]
            for name in log.set_names()} == before_entries
    log.close()


def test_compaction_triggers_on_amplification_threshold(tmp_path):
    log = PageLog(str(tmp_path), compact_threshold=2.0, compact_min_bytes=0)
    payload = os.urandom(4096)
    log.append("a", payload)
    assert log.compactions == 0
    # each supersede adds a dead image; past 2x file/live the append itself
    # pays the rewrite
    for _ in range(4):
        log.append("a", payload, seq=0)
    assert log.compactions >= 1
    assert log.amplification() <= 2.0
    assert log.read("a", 0) == payload
    log.close()


def test_background_compactor_sweeps_without_appends(tmp_path):
    import time as _time
    log = PageLog(str(tmp_path))
    payload = os.urandom(4096)
    log.append("a", payload)
    for _ in range(4):
        log.append("a", payload, seq=0)
    assert log.compactions == 0            # no threshold: inline never fires
    log.compact_threshold = 2.0
    log.compact_min_bytes = 0
    log.start_compactor(interval_s=0.01)
    deadline = _time.time() + 5.0
    while log.compactions == 0 and _time.time() < deadline:
        _time.sleep(0.01)
    log.stop_compactor()
    assert log.compactions >= 1
    assert log.read("a", 0) == payload
    log.close()


def test_compacted_log_replays_and_fscks_clean(tmp_path):
    log = PageLog(str(tmp_path))
    keep = os.urandom(2048)
    log.append("a", os.urandom(2048))
    log.append("a", keep, seq=0)
    log.append("gone", os.urandom(512))
    log.drop_set("gone")
    log.compact()
    log.close()
    # a fresh replay adopts the generation file transparently
    log2 = PageLog(str(tmp_path))
    assert log2.generation == 1
    assert log2.set_names() == ["a"]
    assert log2.read("a", 0) == keep
    log2.close()
    report = fsck(str(tmp_path))
    assert report["exists"] and report["generation"] == 1
    assert report["crc_failures"] == 0 if "crc_failures" in report else True
    assert report["torn_tail_bytes"] == 0
    assert not report["stale_compact_tmp"]


def test_cluster_compaction_knob_bounds_log_growth(tmp_path):
    cluster = _cluster(tmp_path, pagelog_compact_threshold=2.0)
    recs = _pairs(6_000, 500, seed=12)
    sset = cluster.create_sharded_set("t", recs, key_fn=lambda r: r["key"])
    # force supersedes: drop and recreate the same shards repeatedly
    for i in range(4):
        cluster.drop_sharded_set(sset)
        sset = cluster.create_sharded_set("t", _pairs(6_000, 500, seed=12 + i),
                                          key_fn=lambda r: r["key"])
    compactions = sum(node.memory.pagelog.compactions
                      for node in cluster.nodes.values())
    worst = max(node.memory.pagelog.amplification()
                for node in cluster.nodes.values())
    assert compactions >= 1
    assert worst <= 2.5  # bounded; without the knob this walk exceeds 5x
    back = cluster.read_sharded(sset)
    assert len(back) == 6_000
    cluster.shutdown()
