"""Services (paper §8): sequential r/w, shuffle, hash aggregation, join."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BufferPool, DurabilityType, HashService,
                        SequentialWriter, ShuffleService, get_page_iterators,
                        join_service, read_all)
from repro.core.attributes import AttributeSet, ReadingPattern, WritingPattern

PAIR = np.dtype([("key", np.int64), ("val", np.float64)])


def test_sequential_roundtrip_structured():
    pool = BufferPool(1 << 20)
    ls = pool.create_set("d", 1 << 14)
    w = SequentialWriter(pool, ls, PAIR)
    recs = np.zeros(3000, PAIR)
    recs["key"] = np.arange(3000)
    w.append_batch(recs)
    w.close()
    back = read_all(pool, ls, PAIR)
    assert np.array_equal(back["key"], recs["key"])


def test_sequential_roundtrip_subarray_dtype():
    pool = BufferPool(1 << 20)
    ls = pool.create_set("tok", 1 << 14)
    dt = np.dtype((np.int32, (32,)))
    w = SequentialWriter(pool, ls, dt)
    rows = np.arange(64 * 32, dtype=np.int32).reshape(64, 32)
    w.append_batch(rows)
    w.close()
    back = read_all(pool, ls, dt)
    assert np.array_equal(back, rows)


def test_sequential_spill_and_reload():
    """Dataset 4x the pool: MRU paging spills, reads restore transparently."""
    pool = BufferPool(256 * 1024)
    ls = pool.create_set("big", 16 * 1024)
    w = SequentialWriter(pool, ls, PAIR)
    recs = np.zeros(60_000, PAIR)
    recs["key"] = np.arange(60_000)
    w.append_batch(recs)
    w.close()
    assert pool.stats["evictions"] > 0
    back = read_all(pool, ls, PAIR)
    assert np.array_equal(np.sort(back["key"]), np.arange(60_000))


def test_multi_worker_iterators_cover_all_pages():
    pool = BufferPool(1 << 20)
    ls = pool.create_set("d", 4096)
    w = SequentialWriter(pool, ls, PAIR)
    recs = np.zeros(2000, PAIR)
    recs["key"] = np.arange(2000)
    w.append_batch(recs)
    w.close()
    its = get_page_iterators(pool, ls, PAIR, 3)
    seen = np.concatenate([r["key"].copy() for it in its for r in it])
    assert np.array_equal(np.sort(seen), np.arange(2000))


def test_shuffle_partitions_disjoint_and_complete():
    pool = BufferPool(8 << 20)
    sh = ShuffleService(pool, "s", 8, PAIR, page_size=1 << 18)
    rng = np.random.default_rng(0)
    data = np.zeros(30_000, PAIR)
    data["key"] = rng.integers(0, 1 << 40, 30_000)
    for wid in range(4):
        sh.shuffle_batch(wid, data[wid::4], key_fn=lambda r: r["key"])
    sh.finish_writes()
    parts = [sh.read_partition(p) for p in range(8)]
    allk = np.concatenate([p["key"] for p in parts])
    assert len(allk) == 30_000
    assert np.array_equal(np.sort(allk), np.sort(data["key"]))
    for p in range(8):
        assert (parts[p]["key"] % 8 == p).all()


def test_shuffle_spills_under_pressure():
    pool = BufferPool(1 << 20)  # small pool forces spill
    sh = ShuffleService(pool, "s", 4, PAIR, page_size=1 << 17)
    data = np.zeros(80_000, PAIR)
    data["key"] = np.arange(80_000)
    sh.shuffle_batch(0, data, key_fn=lambda r: r["key"])
    sh.finish_writes()
    total = sum(len(sh.read_partition(p)) for p in range(4))
    assert total == 80_000
    assert pool.stats["spill_bytes"] > 0


def test_hash_aggregation_matches_oracle():
    pool = BufferPool(4 << 20)
    hs = HashService(pool, "agg", num_root_partitions=8, page_size=1 << 16)
    rng = np.random.default_rng(1)
    keys = rng.integers(0, 3000, 50_000)
    vals = rng.random(50_000)
    hs.insert(keys, vals)
    k, v = hs.finalize()
    uk = np.unique(keys)
    oracle = {kk: 0.0 for kk in uk.tolist()}
    for kk, vv in zip(keys.tolist(), vals.tolist()):
        oracle[kk] += vv
    assert np.array_equal(k, uk)
    np.testing.assert_allclose(v, [oracle[kk] for kk in k.tolist()],
                               rtol=1e-9)


def test_hash_aggregation_spill_reaggregate():
    """Pool too small for the table: sealed partials spill, finalize
    re-aggregates (paper §8 hash service)."""
    pool = BufferPool(512 * 1024)
    hs = HashService(pool, "agg", num_root_partitions=4, page_size=1 << 15)
    keys = np.arange(200_000) % 50_000
    vals = np.ones(200_000)
    hs.insert(keys, vals)
    k, v = hs.finalize()
    assert len(k) == 50_000
    np.testing.assert_allclose(v, 4.0)
    assert pool.stats["spill_bytes"] > 0


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 50), st.floats(-10, 10)),
                min_size=1, max_size=500))
def test_hash_property_vs_dict(pairs):
    pool = BufferPool(1 << 20)
    hs = HashService(pool, "agg", num_root_partitions=2, page_size=1 << 14)
    keys = np.array([p[0] for p in pairs], np.int64)
    vals = np.array([p[1] for p in pairs], np.float64)
    hs.insert(keys, vals)
    k, v = hs.finalize()
    oracle = {}
    for kk, vv in pairs:
        oracle[kk] = oracle.get(kk, 0.0) + vv
    assert set(k.tolist()) == set(oracle)
    for kk, vv in zip(k.tolist(), v.tolist()):
        assert abs(vv - oracle[kk]) < 1e-6 * max(1.0, abs(oracle[kk])) + 1e-9


def test_join_service_counts():
    pool = BufferPool(1 << 20)
    build = pool.create_set("build", 8192)
    probe = pool.create_set("probe", 8192)
    wb = SequentialWriter(pool, build, PAIR)
    recs = np.zeros(100, PAIR)
    recs["key"] = np.arange(100)
    wb.append_batch(recs)
    wb.close()
    wp = SequentialWriter(pool, probe, PAIR)
    precs = np.zeros(300, PAIR)
    precs["key"] = np.arange(300) % 150  # half match
    wp.append_batch(precs)
    wp.close()
    matches = join_service(pool, build, probe, PAIR, PAIR, "key", "key")
    assert matches[0] == 200  # keys 0..99 appear twice each in probe
