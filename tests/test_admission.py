"""Backpressure-driven admission (ISSUE 5): the pressure signal becomes a
grant. ``MemoryManager.try_reserve`` / ``AdmissionController`` cap in-flight
staging per node, writers block-with-timeout instead of stampeding, the
scheduler re-routes reducers whose planned node refuses admission past the
deadline, and the transfer engine bounds in-flight bytes per destination.

Acceptance scenario (tentpole): an over-capacity shuffle with admission
enabled completes byte-identically to always-grant while reducing destination
spill bytes, and a refused-past-deadline reducer is observably re-routed in
the plan. Plus the PR-5 accounting bugfixes: pressure clears after a burst,
reservation release is idempotent under races, stale recorded pressure falls
back to the live score.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import (BufferPool, MemoryManager, derive_staging_cap)
from repro.core.memory_manager import STAGING_CAP_FLOOR
from repro.runtime.cluster import Cluster, ClusterShuffle
from repro.core.sanitizer import tracked_lock
from repro.runtime.transfer import TransferEngine

PAIR = np.dtype([("key", np.int64), ("val", np.float64)])


def _pairs(n, key_range, seed=0):
    rng = np.random.default_rng(seed)
    recs = np.zeros(n, PAIR)
    recs["key"] = rng.integers(0, key_range, n)
    recs["val"] = rng.random(n)
    return recs


# -- staging admission: try_reserve ------------------------------------------
def test_derive_staging_cap_watermark_and_floor():
    assert derive_staging_cap(100 << 20, 0.85) == int(0.15 * (100 << 20))
    # tiny pools advertise at least one chunk's worth (capped at capacity)
    assert derive_staging_cap(64 << 10, 0.85) == 64 << 10
    assert derive_staging_cap(1 << 20, 0.9) == STAGING_CAP_FLOOR


def test_try_reserve_grants_within_cap_and_counts_refusals():
    mm = MemoryManager(1 << 20, admission_cap=256 << 10)
    held = mm.try_reserve(200 << 10)
    assert held is not None and mm.reserved_bytes == 200 << 10
    # no headroom: "low" refuses immediately, "normal" refuses past timeout
    assert mm.try_reserve(100 << 10, urgency="low") is None
    assert mm.try_reserve(100 << 10, timeout=0.01) is None
    assert mm.admission.refused == 2
    # "required" is forced through rather than refused
    forced = mm.try_reserve(100 << 10, urgency="required", timeout=0.01)
    assert forced is not None
    assert mm.admission.forced == 1
    forced.release()
    held.release()
    assert mm.reserved_bytes == 0
    # with headroom back, a normal ask grants without waiting
    with mm.try_reserve(100 << 10) as r:
        assert r is not None


def test_try_reserve_oversized_request_admits_when_idle():
    """A single request larger than the cap must not starve: a node with no
    staging in flight admits it (the pool spills rather than refuses)."""
    mm = MemoryManager(1 << 20, admission_cap=64 << 10)
    big = mm.try_reserve(512 << 10, urgency="low")
    assert big is not None
    # but piling more on top is refused until it drains
    assert mm.try_reserve(8 << 10, urgency="low") is None
    big.release()
    assert mm.try_reserve(8 << 10, urgency="low") is not None


def test_try_reserve_unblocks_when_peer_releases():
    """Blocking-with-timeout wait: a writer without headroom is woken by a
    peer's release, not the timeout (no deadlock on refusal either way)."""
    mm = MemoryManager(1 << 20, admission_cap=128 << 10)
    held = mm.try_reserve(100 << 10)
    # release the moment the waiter is observably parked — event-driven via
    # the admission notify hook, not a wall-clock timer guess
    releaser = threading.Thread(
        target=lambda: (mm.admission.wait_until(
            lambda: mm.admission.waiting > 0, timeout=10.0), held.release()))
    releaser.start()
    t0 = time.perf_counter()
    res = mm.try_reserve(100 << 10, timeout=10.0)
    waited = time.perf_counter() - t0
    releaser.join()
    assert res is not None
    assert waited < 5.0                      # woken by the release
    assert mm.admission.throttled >= 1
    res.release()
    assert mm.reserved_bytes == 0


# -- reservation release: idempotent + non-negative (satellite) ---------------
def test_release_is_idempotent_under_racing_releasers():
    mm = MemoryManager(1 << 20)
    res = mm.reserve(64 << 10)
    threads = [threading.Thread(target=res.release) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert mm.reserved_bytes == 0            # released exactly once
    res.release()                            # and still a no-op afterwards
    assert mm.reserved_bytes == 0


def test_over_release_asserts_instead_of_going_negative():
    """Accounting corruption must be loud: driving reserved_bytes negative
    (which silently corrupted pressure_score) now trips the lock-held
    assertion."""
    mm = MemoryManager(1 << 20)
    mm.reserve(8 << 10).release()
    with pytest.raises(AssertionError, match="negative"):
        mm._release(1)


# -- pressure accounting bugfix (satellite) -----------------------------------
def test_pressure_clears_after_burst_without_faulting_back():
    """Regression: a node that paged cold data out during a burst used to
    read as under_pressure() forever (spilled_bytes > 0), repelling placement
    even with a nearly empty arena. Paged-out bytes that could fault back
    under the watermark are not pressure."""
    pool = BufferPool(1 << 20)
    mm = pool.memory
    cold = pool.create_set("cold", 1 << 14)
    cold_pages = []
    for i in range(25):                      # 400K of cold data
        p = pool.new_page(cold)
        pool.view(p)[:] = i
        pool.unpin(p, dirty=True)
        cold_pages.append(p)
    burst = pool.create_set("burst", 1 << 14)
    for i in range(80):                      # 1.25M burst pages everything
        p = pool.new_page(burst)
        pool.view(p)[:] = i
        pool.unpin(p, dirty=True)
    assert mm.under_pressure()               # genuinely over capacity
    burst.end_lifetime(pool.clock)
    pool.drop_set(burst)
    # arena nearly empty, cold residue on disk: NOT pressure any more
    assert mm.spilled_bytes > 0
    assert not mm.under_pressure()
    assert mm.pressure_score() == 0.0
    # faulting everything back still balances the books
    for p in cold_pages:
        pool.pin(p)
        pool.unpin(p)
    assert mm.spilled_bytes == 0
    assert not mm.under_pressure()


def test_paged_out_bytes_beyond_headroom_still_pressure():
    """The other side of the fix: when the paged-out bytes could NOT fault
    back under the watermark, the node is still pressured."""
    mm = MemoryManager(1 << 20, pressure_watermark=0.5)
    mm.note_alloc(400 << 10)                 # resident near the watermark
    mm.note_paged_out(300 << 10)             # and a lot paged out
    assert mm.under_pressure()
    assert mm.pressure_score() > 0.0


# -- placement admission + re-route (tentpole) --------------------------------
def test_admit_placement_refuses_full_node_and_waits_for_headroom():
    mm = MemoryManager(1 << 20, pressure_watermark=0.5)
    assert mm.admission.admit_placement(100 << 10)
    mm.note_alloc(600 << 10)                 # past the watermark
    assert not mm.admission.admit_placement(100 << 10, deadline_s=0.01)
    assert mm.admission.refused == 1
    # headroom appearing during the deadline grants the wait
    t = threading.Timer(0.05, lambda: mm.note_free(500 << 10))
    t.start()
    assert mm.admission.admit_placement(100 << 10, deadline_s=10.0)


def _shuffle_two_nodes(cluster, heavy_node=1, light_node=2):
    """One-reducer shuffle whose bytes are mostly on ``heavy_node``."""
    sh = ClusterShuffle(cluster, "p", num_reducers=1, dtype=PAIR)
    probe = np.arange(50_000, dtype=np.int64)
    key0 = probe[sh.partition_of_keys(probe) == 0][0]
    heavy = np.zeros(3_000, PAIR)
    heavy["key"] = key0
    light = np.zeros(500, PAIR)
    light["key"] = key0
    sh.map_batch(heavy_node, heavy, key_fn=lambda p: p["key"])
    sh.map_batch(light_node, light, key_fn=lambda p: p["key"])
    sh.finish_maps()
    return sh


def test_refused_reducer_is_rerouted_and_diversion_recorded():
    cluster = Cluster(4, node_capacity=1 << 20, page_size=1 << 14,
                      replication_factor=0, admission_deadline_s=0.01)
    sh = _shuffle_two_nodes(cluster)
    # byte-locality alone picks node 1
    assert cluster.scheduler.place_reducers("p", 1)[0] == 1
    # node 1 refuses: resident ballast past its watermark
    ballast = _pairs(58_000, 100, seed=1)    # ~928K of a 1M pool
    cluster.nodes[1].write_records("ballast", ballast, PAIR, 1 << 14)
    plan = cluster.scheduler.place_reducers_admitted("p", 1,
                                                     deadline_s=0.01)
    assert plan.placement[0] == 2            # next-best byte candidate
    assert plan.diversions == {0: (1, 2)}    # the diversion is recorded
    assert plan.refusals >= 1
    assert cluster.nodes[1].memory.admission.refused >= 1
    # the shuffle adopts the diverted plan end to end
    sh.place_reducers_locally()
    assert sh.placement[0] == 2
    assert sh.diversions == {0: (1, 2)}
    pulled = sh.pull(0)
    assert len(pulled) == 3_500
    sh.release_reducer(0)
    cluster.shutdown()


def test_all_nodes_refusing_keeps_byte_heaviest_plan():
    """When every candidate refuses past the deadline, someone must still
    run the reducer: the byte-heaviest node keeps it (spill, don't fail)."""
    cluster = Cluster(4, node_capacity=1 << 20, page_size=1 << 14,
                      replication_factor=0)
    sh = _shuffle_two_nodes(cluster)
    ballast = _pairs(58_000, 100, seed=2)
    for nid in cluster.alive_node_ids():
        cluster.nodes[nid].write_records(f"ballast{nid}", ballast, PAIR,
                                         1 << 14)
    plan = cluster.scheduler.place_reducers_admitted("p", 1,
                                                     deadline_s=0.01)
    assert plan.placement[0] == 1            # nobody admitted; locality wins
    assert plan.diversions == {}
    assert plan.refusals >= 2                # but the refusals were counted
    sh.place_reducers_locally()
    assert len(sh.pull(0)) == 3_500
    sh.release_reducer(0)
    cluster.shutdown()


# -- stale pressure fallback (satellite) --------------------------------------
def test_stale_recorded_pressure_falls_back_to_live_score():
    """Regression: pressure is published at shuffle finalization, so a
    back-to-back job used to plan against the previous job's snapshot. Any
    topology/job event since the recording invalidates it and placement
    reads the node's live MemoryManager score instead."""
    cluster = Cluster(4, node_capacity=16 << 20, page_size=1 << 16,
                      replication_factor=0)
    sh = _shuffle_two_nodes(cluster)
    assert cluster.scheduler.place_reducers("p", 1)[0] == 1
    # a recorded snapshot says node 1 is saturated -> placement avoids it
    cluster.stats.record_node_pressure(1, 1.0)
    assert cluster.scheduler.place_reducers("p", 1)[0] == 2
    # a job boundary makes that snapshot stale; node 1's live score is 0,
    # so its byte locality wins again
    cluster.stats.note_event()
    assert cluster.stats.node_pressure_fresh(1) is None
    assert cluster.stats.node_pressure(1) == 1.0   # raw view keeps history
    assert cluster.scheduler.place_reducers("p", 1)[0] == 1
    sh.place_reducers_locally()
    sh.release_partition(0)
    cluster.shutdown()


def test_clear_shuffle_is_a_job_event():
    cluster = Cluster(2, node_capacity=1 << 20, replication_factor=0)
    cluster.stats.record_node_pressure(0, 0.9)
    assert cluster.stats.node_pressure_fresh(0) == 0.9
    cluster.stats.clear_shuffle("whatever")
    assert cluster.stats.node_pressure_fresh(0) is None
    cluster.shutdown()


# -- transfer engine per-destination caps (tentpole) --------------------------
def test_transfer_engine_caps_inflight_bytes_per_destination():
    engine = TransferEngine(4, name="adm-test", dest_inflight_cap=100)
    lock = tracked_lock("test.adm")
    state = {"now": 0, "peak": 0}

    def job():
        with lock:
            state["now"] += 1
            state["peak"] = max(state["peak"], state["now"])
        time.sleep(0.02)
        with lock:
            state["now"] -= 1

    futs = [engine.submit(job, dest="n0", nbytes=60) for _ in range(6)]
    for f in futs:
        f.result(timeout=30)
    assert state["peak"] == 1                # 60+60 > 100: one at a time
    assert engine.dest_holds > 0
    # different destinations are independent
    state["now"] = state["peak"] = 0
    futs = [engine.submit(job, dest=f"n{i}", nbytes=60) for i in range(4)]
    for f in futs:
        f.result(timeout=30)
    assert state["peak"] > 1
    # oversized single jobs still run (admit-when-idle), unmetered jobs too
    engine.submit(job, dest="n9", nbytes=500).result(timeout=30)
    engine.submit(job).result(timeout=30)
    engine.shutdown()


def test_transfer_engine_raising_callable_fails_job_not_engine():
    """A raising dest/nbytes callable must fail that job's future — not
    leak the inflight count (hanging drain/shutdown) or kill a worker."""
    engine = TransferEngine(2, name="adm-test3", dest_inflight_cap=100)
    # raise on the submit path (deps already done)
    f = engine.submit(lambda: 1, dest=lambda: {}["missing"], nbytes=10)
    with pytest.raises(KeyError):
        f.result(timeout=5)
    # raise on the deferred path (resolved in _promote_ready after deps)
    dep = engine.submit(time.sleep, 0.02)
    f2 = engine.submit(lambda: 1, after=[dep],
                       dest=lambda: {}["missing"], nbytes=10)
    with pytest.raises(KeyError):
        f2.result(timeout=5)
    # the engine still runs work and drains cleanly
    assert engine.submit(lambda: 42).result(timeout=5) == 42
    engine.drain(timeout=5)
    engine.shutdown()


def test_transfer_engine_resolves_callable_dest_after_deps():
    """A pull submitted before placement declares dest/nbytes as callables;
    they must resolve only once the placement dependency finished."""
    engine = TransferEngine(2, name="adm-test2", dest_inflight_cap=1000)
    placed = {}

    def place():
        time.sleep(0.02)
        placed["node"] = "n7"

    def pull():
        return placed["node"]

    f_place = engine.submit(place)
    f_pull = engine.submit(pull, after=[f_place],
                           dest=lambda: placed["node"], nbytes=lambda: 10)
    assert f_pull.result(timeout=30) == "n7"
    engine.shutdown()


# -- threaded writers against one pressured node (satellite) ------------------
def test_threaded_map_writers_bounded_inflight_no_deadlock_identical():
    """Concurrent map writers feeding one node throttle against its staging
    cap: the node's reservation HWM stays bounded, nothing deadlocks, and
    the shuffle output is byte-identical to the always-grant run. The
    driver holds a staging grant across the barrier release so writer
    contention is deterministic — left to scheduling luck, a whole
    map_batch can run reserve-to-release without any overlap and the
    throttle this test asserts on never materializes."""
    batches = [_pairs(2_000, 1 << 40, seed=100 + i) for i in range(12)]

    def run(admission):
        cluster = Cluster(4, node_capacity=8 << 20, page_size=1 << 14,
                          replication_factor=0, admission=admission,
                          admission_timeout_s=30.0)
        mm = cluster.nodes[0].memory
        cap = 40 << 10                       # tight: one 32K batch at a time
        mm.admission.cap = cap
        mm.reset_reserved_hwm()
        sh = ClusterShuffle(cluster, "t", num_reducers=4, dtype=PAIR)
        errors = []
        barrier = threading.Barrier(len(batches) + 1)  # writers + driver

        def writer(idx):
            try:
                barrier.wait()               # all writers hit the node at once
                sh.map_batch(0, batches[idx], key_fn=lambda p: p["key"])
            except Exception as e:  # noqa: BLE001 - surface thread crashes
                errors.append(repr(e))

        threads = [threading.Thread(target=writer, args=(i,))
                   for i in range(len(batches))]
        for t in threads:
            t.start()
        # pre-hold a grant so writers arriving behind the barrier find the
        # cap taken, and keep holding until one is observably parked on the
        # condition variable (released well inside their 30s timeout, so
        # they are throttled — never forced)
        hold = mm.try_reserve(32 << 10, urgency="low") if admission else None
        barrier.wait()
        if hold is not None:
            # event-driven (no wall-clock polling): wait_until parks on the
            # admission condition variable and wakes on the "waiting" notify
            assert mm.admission.wait_until(
                lambda: mm.admission.waiting > 0, timeout=10.0)
            hold.release()
        for t in threads:
            t.join()
        assert errors == []
        hwm = mm.reserved_hwm
        assert mm.reserved_bytes == 0        # every grant released
        sh.finish_maps()
        out = []
        for r in range(4):
            out.append(np.sort(sh.pull(r), order=["key", "val"]).copy())
            sh.release_reducer(r)
        cluster.shutdown()
        return hwm, out, mm.admission

    hwm_on, out_on, adm = run(admission=True)
    hwm_off, out_off, _ = run(admission=False)
    # bounded in-flight: the reservation HWM proves grants were serialized
    # under the cap, and no forced grants happened with the generous timeout
    # (admission-off writers never reserve — they stampede the pool raw)
    assert 0 < hwm_on <= (40 << 10)
    assert adm.forced == 0
    assert adm.throttled > 0                 # writers really took turns
    assert hwm_off == 0
    for a, b in zip(out_on, out_off):
        assert np.array_equal(a.view(np.uint8).reshape(len(a), -1),
                              b.view(np.uint8).reshape(len(b), -1))


# -- over-capacity shuffle: admission vs always-grant (acceptance) ------------
def _admission_run(recs, admission):
    """Mini version of the bench workload: ballast the byte-heaviest node so
    it refuses, then place + pull; returns keys, pull-phase spill delta on
    the hot node, and the diversions."""
    cap = 1 << 20
    cluster = Cluster(4, node_capacity=cap, page_size=1 << 14,
                      replication_factor=0, admission=admission,
                      admission_deadline_s=0.01)
    sset = cluster.create_sharded_set("src", recs, key_fn=lambda r: r["key"])
    sh = ClusterShuffle(cluster, "sh", num_reducers=4, dtype=PAIR)
    sh.map_sharded(sset, key_fn=lambda r: r["key"])
    sh.finish_maps()
    hot = max(cluster.alive_node_ids(), key=lambda nid: sum(
        cluster.stats.shuffle_partition_bytes("sh", r).get(nid, 0)
        for r in range(4)))
    headroom = cap - cluster.nodes[hot].memory.resident_bytes
    ballast = np.zeros(max(1, (headroom * 3 // 4) // PAIR.itemsize), PAIR)
    cluster.nodes[hot].write_records("ballast", ballast, PAIR, 1 << 14)
    spill0 = sum(node.memory.stats["spill_bytes"]
                 for node in cluster.nodes.values())
    sh.place_reducers_locally()
    placement = dict(sh.placement)
    keys = []
    for r in range(4):
        keys.append(sh.pull(r)["key"].copy())
        sh.release_reducer(r)
    spill = sum(node.memory.stats["spill_bytes"]
                for node in cluster.nodes.values()) - spill0
    out = (np.sort(np.concatenate(keys)), spill, dict(sh.diversions),
           placement, hot)
    cluster.shutdown()
    return out


def test_both_shuffled_join_diverts_and_stays_byte_identical():
    """place_join_reducers_admitted: a both-sides-shuffled join re-routes
    reducers away from pressured nodes (JoinReport.diversions) and its
    output is byte-identical to the always-grant run."""
    from repro.runtime.join import ClusterJoin

    def run(admission):
        cluster = Cluster(4, node_capacity=1 << 20, page_size=1 << 14,
                          replication_factor=0, admission=admission,
                          admission_deadline_s=0.01)
        # both sides live on nodes 0-2 only and are NOT partitioned on
        # "key" -> both sides shuffle, all map output sits on nodes 0-2
        build = cluster.create_sharded_set(
            "b", _pairs(30_000, 400, seed=5), key_fn=lambda r: r["key"],
            node_ids=[0, 1, 2])
        probe = cluster.create_sharded_set(
            "p", _pairs(30_000, 400, seed=6), key_fn=lambda r: r["key"],
            node_ids=[0, 1, 2])
        out, report = ClusterJoin(cluster, build, probe, "key",
                                  num_reducers=4).execute()
        cluster.shutdown()
        return out, report

    out_on, rep_on = run(True)
    out_off, rep_off = run(False)
    assert rep_on.plan.shuffle_sides == ("build", "probe")
    assert np.array_equal(out_on.view(np.uint8).reshape(len(out_on), -1),
                          out_off.view(np.uint8).reshape(len(out_off), -1))
    assert rep_off.diversions == {}
    # nodes 0-2 hold ~1M of shards + map output each (past the watermark);
    # idle node 3 holds zero bytes but admission headroom: refused
    # partitions divert there instead of spilling through a full pool
    assert rep_on.diversions
    assert all(to == 3 for _refused, to in rep_on.diversions.values())


def test_admission_reduces_destination_spill_byte_identical():
    # ~960K of pairs through 1M pools: the cluster as a whole has headroom,
    # but the ballasted byte-heaviest node does not — the always-grant plan
    # pins reducers there anyway and pays in destination spill
    rng = np.random.default_rng(3)
    recs = np.zeros(60_000, PAIR)
    recs["key"] = rng.zipf(1.3, len(recs)).astype(np.int64)
    recs["val"] = rng.random(len(recs))
    k_on, spill_on, div_on, placement_on, hot = _admission_run(recs, True)
    k_off, spill_off, div_off, placement_off, _ = _admission_run(recs, False)
    # byte-identical shuffle output
    assert np.array_equal(k_on, k_off)
    assert len(k_on) == len(recs)
    # always-grant pinned reducers to the refusing hot node; admission
    # observably re-routed at least one of them and recorded the diversion
    assert div_off == {}
    assert div_on
    assert all(refused == hot for refused, _to in div_on.values())
    assert all(placement_on[r] != hot for r in div_on)
    assert hot in placement_off.values()
    # and the diverted reducers stopped paying destination spill
    assert spill_on < spill_off


# -- straggler backup admission (PR 6 carried bugfix) -------------------------
def test_straggler_backup_diverted_off_pressured_holder():
    """Regression: ``reexecute_stragglers`` used to hand the backup task to
    the first surviving copy regardless of pressure — the one placement
    decision the PR-5 admission loop missed. The pressured holder must now
    refuse and the task land on the next copy, with the diversion recorded."""
    cluster = Cluster(4, node_capacity=1 << 20, page_size=1 << 14,
                      replication_factor=2, admission_deadline_s=0.01)
    recs = _pairs(20_000, 1_500, seed=40)
    sset = cluster.create_sharded_set("st", recs, key_fn=lambda r: r["key"])
    sh = ClusterShuffle(cluster, "st.sh", 4, PAIR)
    sh.map_sharded(sset, key_fn=lambda r: r["key"])
    straggler = 2
    first, second = [h for h, _ in sset.shards[straggler].replicas]
    # resident ballast pushes the first backup candidate past its watermark
    ballast = _pairs(58_000, 100, seed=41)
    cluster.nodes[first].write_records("ballast", ballast, PAIR, 1 << 14)
    redone = sh.reexecute_stragglers([straggler])
    assert redone and redone[0] == (straggler, second)
    assert (straggler, first, second) in sh.backup_diversions
    assert cluster.nodes[first].memory.admission.refused >= 1
    sh.finish_maps()
    allk = np.concatenate([sh.pull(r)["key"] for r in range(4)])
    assert np.array_equal(np.sort(allk), np.sort(recs["key"]))
    cluster.shutdown()


def test_straggler_backup_all_refusing_keeps_first_copy():
    """Every candidate refusing must not strand the work: the first copy
    keeps it (spill, don't fail) and no diversion is recorded."""
    cluster = Cluster(4, node_capacity=1 << 20, page_size=1 << 14,
                      replication_factor=2, admission_deadline_s=0.01)
    recs = _pairs(20_000, 1_500, seed=42)
    sset = cluster.create_sharded_set("st", recs, key_fn=lambda r: r["key"])
    sh = ClusterShuffle(cluster, "st.sh", 4, PAIR)
    sh.map_sharded(sset, key_fn=lambda r: r["key"])
    straggler = 2
    first, _second = [h for h, _ in sset.shards[straggler].replicas]
    ballast = _pairs(58_000, 100, seed=43)
    for nid in cluster.alive_node_ids():
        if nid != straggler:
            cluster.nodes[nid].write_records(f"bal{nid}", ballast, PAIR,
                                             1 << 14)
    redone = sh.reexecute_stragglers([straggler])
    assert redone and redone[0] == (straggler, first)
    assert sh.backup_diversions == []
    sh.finish_maps()
    allk = np.concatenate([sh.pull(r)["key"] for r in range(4)])
    assert np.array_equal(np.sort(allk), np.sort(recs["key"]))
    cluster.shutdown()
