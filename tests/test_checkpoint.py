"""Checkpointing: roundtrip, heterogeneous-layout recovery, async, GC."""
import os

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _state():
    rng = np.random.default_rng(0)
    return {"params": {"w1": rng.normal(size=(16, 8)).astype(np.float32),
                       "w2": rng.normal(size=(8, 16)).astype(np.float32),
                       "scale": rng.normal(size=(7,)).astype(np.float32)},
            "opt": {"step": np.int32(5),
                    "m": {"w1": rng.normal(size=(16, 8)).astype(np.float32)}}}


def _assert_equal(a, b):
    if isinstance(a, dict):
        assert set(a) == set(b)
        for k in a:
            _assert_equal(a[k], b[k])
    else:
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_roundtrip_both_layouts(tmp_path):
    mgr = CheckpointManager(str(tmp_path), layouts=("row", "col"),
                            num_shards=4)
    st = _state()
    mgr.save(1, st)
    for layout in ("row", "col"):
        back = mgr.restore(st, layout=layout)
        _assert_equal(back, st)


@pytest.mark.parametrize("damaged_layout,shard", [("row", 0), ("row", 3),
                                                  ("col", 1)])
def test_recovery_from_other_layout(tmp_path, damaged_layout, shard):
    """Paper §7: a lost shard of one partitioning is rebuilt from the
    differently partitioned replica."""
    mgr = CheckpointManager(str(tmp_path), layouts=("row", "col"),
                            num_shards=4)
    st = _state()
    mgr.save(2, st)
    mgr.damage_shard(2, damaged_layout, shard)
    back = mgr.restore(st)
    _assert_equal(back, st)


def test_damage_in_both_layouts_different_shards(tmp_path):
    """Per-tensor salvage: each tensor recovered from whichever layout still
    holds it intact."""
    mgr = CheckpointManager(str(tmp_path), layouts=("row", "col"),
                            num_shards=4)
    st = _state()
    mgr.save(3, st)
    mgr.damage_shard(3, "row", 0)
    mgr.damage_shard(3, "col", 2)
    # row shard 0 and col shard 2 damage different tensors' pieces; restore
    # must round-trip via per-tensor salvage when every tensor is whole in
    # at least one layout, else raise cleanly
    try:
        back = mgr.restore(st)
        _assert_equal(back, st)
    except IOError:
        pytest.skip("overlapping damage — unrecoverable by design")


def test_async_save_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), layouts=("row",), num_shards=2,
                            keep=2)
    st = _state()
    for step in (1, 2, 3, 4):
        mgr.save(step, st, async_=True)
    mgr.wait()
    assert mgr.latest_step() == 4
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(dirs) == 2  # GC kept last 2


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore(_state())


# -- pool mode (PR 6): checkpoints stream through cluster pools --------------
def _pool_cluster(tmp_path):
    from repro.runtime.cluster import Cluster
    return Cluster(4, node_capacity=16 << 20, page_size=1 << 16,
                   replication_factor=1,
                   pagelog_dir=str(tmp_path / "pagelog"))


def test_pool_mode_roundtrip_both_layouts(tmp_path):
    cluster = _pool_cluster(tmp_path)
    mgr = CheckpointManager(cluster=cluster, layouts=("row", "col"),
                            num_shards=4)
    st = _state()
    mgr.save(1, st)
    for layout in ("row", "col"):
        _assert_equal(mgr.restore(st, layout=layout), st)
    cluster.shutdown()


def test_pool_mode_requires_exactly_one_backend(tmp_path):
    cluster = _pool_cluster(tmp_path)
    with pytest.raises(ValueError):
        CheckpointManager(str(tmp_path / "d"), cluster=cluster)
    with pytest.raises(ValueError):
        CheckpointManager()
    cluster.shutdown()


def test_pool_mode_damage_recovers_from_other_layout(tmp_path):
    cluster = _pool_cluster(tmp_path)
    mgr = CheckpointManager(cluster=cluster, layouts=("row", "col"),
                            num_shards=4)
    st = _state()
    mgr.save(2, st)
    mgr.damage_shard(2, "row", 1)
    _assert_equal(mgr.restore(st), st)
    cluster.shutdown()


def test_pool_mode_survives_full_cluster_restart(tmp_path):
    """The durable tier is the point: kill every node, warm-revive, and the
    checkpoint restores purely from the local page logs — the revival fence
    keeps registered durable blobs."""
    cluster = _pool_cluster(tmp_path)
    mgr = CheckpointManager(cluster=cluster, layouts=("row",), num_shards=4)
    st = _state()
    mgr.save(7, st)
    for n in list(cluster.nodes):
        cluster.kill_node(n)
    for n in list(cluster.nodes):
        assert cluster.revive_node(n) == []   # nothing fenced: blobs valid
    _assert_equal(mgr.restore(st), st)
    assert mgr.latest_step() == 7
    cluster.shutdown()


def test_pool_mode_gc_keeps_newest(tmp_path):
    cluster = _pool_cluster(tmp_path)
    mgr = CheckpointManager(cluster=cluster, layouts=("row",), num_shards=2,
                            keep=2)
    st = _state()
    for step in (1, 2, 3):
        mgr.save(step, st)
    assert mgr._list_steps() == ["step_00000002", "step_00000003"]
    _assert_equal(mgr.restore(st), st)
    # dropped steps freed their durable blobs too
    live = [n for n in cluster.durable_blobs if "step_00000001" in n]
    assert live == []
    cluster.shutdown()
