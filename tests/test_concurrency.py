"""Threaded buffer-pool safety: pin/unpin from many threads (paper §5's
reference counting) must never corrupt pin counts, double-free arena blocks,
or evict a pinned page."""
import threading

import numpy as np
import pytest

from repro.core import BufferPool, PoolExhaustedError

THREADS = 8
ITERS = 200


def test_concurrent_pin_unpin_shared_pages():
    """8 threads hammering pin/unpin on a shared set: pin counts stay
    consistent, pages are resident whenever the pinner holds them, and the
    final pin count is exactly zero."""
    pool = BufferPool(4 << 20)
    ls = pool.create_set("shared", 4096)
    pages = []
    for _ in range(16):
        p = pool.new_page(ls)
        pool.unpin(p, dirty=True)
        pages.append(p)
    errors = []
    barrier = threading.Barrier(THREADS)

    def worker(tid):
        rng = np.random.default_rng(tid)
        barrier.wait()
        try:
            for _ in range(ITERS):
                page = pages[rng.integers(0, len(pages))]
                view = pool.pin(page)
                try:
                    if page.pin_count <= 0:
                        errors.append(f"pin_count {page.pin_count} while held")
                    if not page.resident:
                        errors.append("page evicted while pinned")
                    view[:8]  # touch the mapping
                finally:
                    pool.unpin(page)
        except Exception as e:  # noqa: BLE001 - surface any thread crash
            errors.append(repr(e))

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errors == []
    for p in pages:
        assert p.pin_count == 0
    with pytest.raises(ValueError):
        pool.unpin(pages[0])  # pool still detects over-unpin afterwards


def test_concurrent_writers_under_eviction_pressure():
    """Each thread writes its own set into a pool sized so that eviction runs
    constantly. No double-free (TLSF raises on those), no negative pins, no
    evicted-while-pinned, and every thread's pages stay accounted for."""
    pool = BufferPool(1 << 20)  # small: forces cross-thread eviction
    sets = [pool.create_set(f"t{t}", 8192) for t in range(THREADS)]
    errors = []
    barrier = threading.Barrier(THREADS)

    def worker(tid):
        rng = np.random.default_rng(100 + tid)
        ls = sets[tid]
        mine = []
        barrier.wait()
        try:
            for i in range(ITERS // 2):
                page = pool.new_page(ls)
                pool.view(page)[:8] = tid  # write while pinned
                if not page.resident:
                    errors.append("fresh page not resident")
                pool.unpin(page, dirty=True)
                mine.append(page)
                if rng.random() < 0.5:
                    probe = mine[rng.integers(0, len(mine))]
                    back = pool.pin(probe)
                    if int(back[0]) != tid:
                        errors.append(f"t{tid}: page content corrupted")
                    pool.unpin(probe)
        except PoolExhaustedError:
            pass  # acceptable under extreme pressure; not a safety violation
        except Exception as e:  # noqa: BLE001
            errors.append(repr(e))

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errors == []
    for ls in sets:
        for p in ls.pages.values():
            assert p.pin_count == 0, f"leaked pin on page {p.page_id}"
            assert p.pin_count >= 0
