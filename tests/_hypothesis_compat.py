"""Minimal `hypothesis` fallback so the suite runs without the package.

When the real ``hypothesis`` is importable, :func:`install` is a no-op and the
tests use it unchanged. When it is missing, a tiny stand-in module is placed in
``sys.modules`` that degenerates ``@given`` into a seeded-random example sweep:
each strategy draws ``max_examples`` pseudo-random examples from a
deterministic PRNG, so the property tests still exercise randomized inputs
reproducibly — just without shrinking or the database.

Only the strategy surface the suite uses is implemented: ``integers``,
``floats``, ``booleans``, ``lists``, ``tuples``, plus ``settings`` /
``HealthCheck`` / ``assume`` shims.
"""
from __future__ import annotations

import random
import sys
import types
import zlib

DEFAULT_MAX_EXAMPLES = 25
_SEED = 0x9A16EA  # deterministic sweep seed


class _Strategy:
    """A draw function wrapped for composition."""

    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float, *, allow_nan: bool = False,
           allow_infinity: bool = False) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def tuples(*elements: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(e.example_from(rng) for e in elements))


def lists(element: _Strategy, *, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(rng: random.Random):
        n = rng.randint(min_size, max_size)
        return [element.example_from(rng) for _ in range(n)]
    return _Strategy(draw)


class settings:  # noqa: N801 - mirrors hypothesis' lowercase class
    """Decorator shim: records max_examples for the @given sweep."""

    def __init__(self, max_examples: int = DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._compat_max_examples = self.max_examples
        return fn


def given(*strategies: _Strategy, **kw_strategies: _Strategy):
    """Seeded-random sweep replacement for hypothesis' @given."""

    def decorate(fn):
        def wrapper(*args, **kwargs):
            # @settings above @given decorates THIS wrapper, so look here
            # first; @settings below @given lands on fn
            n = getattr(wrapper, "_compat_max_examples",
                        getattr(fn, "_compat_max_examples",
                                DEFAULT_MAX_EXAMPLES))
            # crc32, not hash(): the latter is salted per process and would
            # make failures irreproducible across runs
            rng = random.Random(_SEED ^ zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                ex_args = tuple(s.example_from(rng) for s in strategies)
                ex_kwargs = {k: s.example_from(rng)
                             for k, s in kw_strategies.items()}
                fn(*args, *ex_args, **kwargs, **ex_kwargs)
        # NOT functools.wraps: copying __wrapped__ would make pytest see the
        # strategy parameters in the signature and demand fixtures for them
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return decorate


def assume(condition: bool) -> bool:
    """Real hypothesis aborts the example; the sweep just skips via return
    value — property bodies in this suite don't use assume, so a plain
    truthiness passthrough is enough."""
    return bool(condition)


class HealthCheck:
    all = staticmethod(lambda: [])
    too_slow = filter_too_much = data_too_large = None


def install() -> bool:
    """Install the shim as ``hypothesis`` if the real package is missing.
    Returns True when the shim was installed, False when real hypothesis
    is available."""
    try:
        import hypothesis  # noqa: F401
        return False
    except ImportError:
        # missing on purpose: the shim below is the substitute, built right
        # here so the handler visibly does something (R7)
        mod = types.ModuleType("hypothesis")
        mod.given = given
        mod.settings = settings
        mod.assume = assume
        mod.HealthCheck = HealthCheck
        strategies = types.ModuleType("hypothesis.strategies")
        for name in ("integers", "floats", "booleans", "tuples", "lists"):
            setattr(strategies, name, globals()[name])
        mod.strategies = strategies
        sys.modules["hypothesis"] = mod
        sys.modules["hypothesis.strategies"] = strategies
        return True
