"""Scheduler-driven distributed joins (paper §9.2.2): shuffle only the
non-co-partitioned side — or nothing at all.

The ISSUE-4 acceptance scenarios: a co-partitioned ``cluster_join`` moves 0
network bytes; non-co joins shuffle only the smaller/non-co side; and every
execution mode (including forced build-side spill and dead-owner replica
reads) is byte-identical to the single-pool ``join_records`` reference after
the shared canonical sort.
"""
import numpy as np

from repro.core import BufferPool, SequentialWriter
from repro.core.services import (JoinService, canonical_join_sort,
                                 join_output_dtype, join_records)
from repro.data.pipeline import cluster_join
from repro.runtime.cluster import Cluster
from repro.runtime.join import ClusterJoin, scheme_slot_of_keys
from repro.runtime.watchdog import StepTimer

BUILD = np.dtype([("key", np.int64), ("rid", np.int64), ("bval", np.float64)])
PROBE = np.dtype([("key", np.int64), ("rid", np.int64), ("pval", np.float64)])


def _records(dtype, n, key_range, seed=0, val_field="bval", zipf=None):
    rng = np.random.default_rng(seed)
    recs = np.zeros(n, dtype)
    if zipf is None:
        recs["key"] = rng.integers(0, key_range, n)
    else:
        recs["key"] = rng.zipf(zipf, n).astype(np.int64) % key_range
    recs["rid"] = np.arange(n)
    recs[val_field] = rng.random(n)
    return recs


def _sides(nb=4_000, np_=12_000, bkeys=1_500, pkeys=2_000, seed=0, zipf=None):
    build = _records(BUILD, nb, bkeys, seed=seed, val_field="bval", zipf=zipf)
    probe = _records(PROBE, np_, pkeys, seed=seed + 1, val_field="pval",
                     zipf=zipf)
    return build, probe


def _reference(brecs, precs):
    """Single-pool join over the same records — the byte-identity oracle."""
    pool = BufferPool(128 << 20)
    bls = pool.create_set("ref.b", 1 << 16)
    w = SequentialWriter(pool, bls, BUILD)
    if len(brecs):
        w.append_batch(brecs)
    w.close()
    pls = pool.create_set("ref.p", 1 << 16)
    w = SequentialWriter(pool, pls, PROBE)
    if len(precs):
        w.append_batch(precs)
    w.close()
    return join_records(pool, bls, pls, BUILD, PROBE, "key", "key")


def _oracle(brecs, precs):
    """Brute-force numpy join (independent of any pool machinery)."""
    out_dtype = join_output_dtype(BUILD, PROBE, "key", "key")
    rows = []
    for p in precs:
        for b in brecs[brecs["key"] == p["key"]]:
            rows.append((p["key"], b["rid"], b["bval"], p["rid"], p["pval"]))
    return canonical_join_sort(np.array(rows, out_dtype))


def _cluster(replication_factor=0, **kw):
    kw.setdefault("node_capacity", 32 << 20)
    kw.setdefault("page_size", 1 << 16)
    return Cluster(4, replication_factor=replication_factor, **kw)


# -- single-pool join service -------------------------------------------------
def test_join_service_matches_bruteforce_oracle():
    brecs, precs = _sides(nb=300, np_=900, bkeys=80, pkeys=120)
    ref = _reference(brecs, precs)
    oracle = _oracle(brecs, precs)
    assert ref.dtype == oracle.dtype
    assert ref.tobytes() == oracle.tobytes()


def test_join_records_empty_sides():
    brecs, precs = _sides(nb=200, np_=400)
    assert len(_reference(brecs[:0], precs)) == 0
    assert len(_reference(brecs, precs[:0])) == 0
    empty = _reference(brecs[:0], precs[:0])
    assert empty.dtype == join_output_dtype(BUILD, PROBE, "key", "key")


def test_join_service_build_spills_through_pool():
    """A build side several times the pool budget spills (pages evicted to
    the spill store) and probes fault the pages back — same answer."""
    pool = BufferPool(192 << 10, policy="data-aware")
    brecs, precs = _sides(nb=30_000, np_=2_000, bkeys=500, pkeys=500)
    js = JoinService(pool, "spilljoin", BUILD, PROBE, "key", "key",
                     page_size=1 << 13)
    for i in range(0, len(brecs), 4096):
        js.build_batch(brecs[i:i + 4096])
    js.finish_build()
    assert pool.spill.write_ops > 0          # the build did not fit
    out = canonical_join_sort(js.probe_batch(precs))
    js.close()
    assert out.tobytes() == _reference(brecs, precs).tobytes()


# -- plan_join ----------------------------------------------------------------
def test_plan_join_co_partitioned_elides_all_shuffles():
    cluster = _cluster()
    brecs, precs = _sides()
    b = cluster.create_sharded_set("b", brecs, key_fn=lambda r: r["key"],
                                   partition_key="key")
    p = cluster.create_sharded_set("p", precs, key_fn=lambda r: r["key"],
                                   partition_key="key")
    plan = cluster.scheduler.plan_join(b, p, "key")
    assert plan.shuffle_free and plan.shuffle_sides == ()


def test_plan_join_shuffles_only_the_non_co_side():
    cluster = _cluster()
    brecs, precs = _sides()
    b = cluster.create_sharded_set("b", brecs, key_fn=lambda r: r["key"],
                                   partition_key="key")
    p = cluster.create_sharded_set("p", precs, key_fn=lambda r: r["rid"],
                                   partition_key="rid")
    plan = cluster.scheduler.plan_join(b, p, "key")
    assert plan.shuffle_sides == ("probe",) and plan.anchor == "build"
    # and symmetrically when the probe side is the co one
    plan2 = cluster.scheduler.plan_join(p, b, "key")
    assert plan2.shuffle_sides == ("build",) and plan2.anchor == "probe"


def test_plan_join_misaligned_co_sides_move_only_the_smaller():
    """Both sides partitioned on the key but onto different layouts: the
    byte-heavier side anchors, the smaller one is re-shuffled to match."""
    cluster = _cluster()
    brecs, precs = _sides(nb=2_000, np_=12_000)
    small = cluster.create_sharded_set("small", brecs,
                                       key_fn=lambda r: r["key"],
                                       partition_key="key",
                                       node_ids=[0, 1])
    big = cluster.create_sharded_set("big", precs,
                                     key_fn=lambda r: r["key"],
                                     partition_key="key")
    plan = cluster.scheduler.plan_join(small, big, "key")
    assert plan.shuffle_sides == ("build",) and plan.anchor == "probe"


def test_scheme_slot_routing_matches_storage_placement():
    cluster = _cluster()
    brecs, _ = _sides()
    b = cluster.create_sharded_set("b", brecs, key_fn=lambda r: r["key"],
                                   partition_key="key")
    slots = scheme_slot_of_keys(brecs["key"], b.scheme)
    routed = np.asarray(b.node_ids)[slots]
    assert np.array_equal(routed, b.node_of_records(brecs))


# -- distributed execution vs the single-pool reference -----------------------
def test_co_partitioned_cluster_join_moves_zero_network_bytes():
    cluster = _cluster()
    brecs, precs = _sides()
    out, report = cluster_join(cluster, "j", brecs, precs, "key")
    assert report.shuffle_free
    assert report.net_bytes == 0
    assert cluster.net_bytes == 0            # the acceptance criterion
    assert out.tobytes() == _reference(brecs, precs).tobytes()


def test_one_side_join_shuffles_only_probe_bytes():
    cluster = _cluster()
    brecs, precs = _sides(zipf=1.3)
    out, report = cluster_join(cluster, "j", brecs, precs, "key",
                               probe_partition_field="rid")
    assert report.plan.shuffle_sides == ("probe",)
    assert set(report.shuffled_bytes) == {"probe"}   # build never moved
    assert report.shuffled_bytes["probe"] == len(precs) * PROBE.itemsize
    assert 0 < report.net_bytes <= report.shuffled_bytes["probe"]
    assert out.tobytes() == _reference(brecs, precs).tobytes()


def test_both_sides_shuffled_join_matches_reference():
    cluster = _cluster()
    brecs, precs = _sides(zipf=1.3)
    out, report = cluster_join(cluster, "j", brecs, precs, "key",
                               build_partition_field="rid",
                               probe_partition_field="rid")
    assert report.plan.shuffle_sides == ("build", "probe")
    assert set(report.shuffled_bytes) == {"build", "probe"}
    assert report.net_bytes > 0
    assert out.tobytes() == _reference(brecs, precs).tobytes()


def test_join_routes_through_registered_co_partitioned_replica():
    """A by-key replica registered for a non-co handle makes the join
    shuffle-free even when queried through the non-co set — the paper's
    'select a Pangea replica that is the best for the query'."""
    cluster = _cluster()
    brecs, precs = _sides()
    b = cluster.create_sharded_set("orders", brecs,
                                   key_fn=lambda r: r["rid"],
                                   partition_key="rid")
    by_key = cluster.create_sharded_set("orders_by_key", brecs,
                                        key_fn=lambda r: r["key"],
                                        partition_key="key")
    cluster.register_replica_set("orders", by_key)
    p = cluster.create_sharded_set("lineitems", precs,
                                   key_fn=lambda r: r["key"],
                                   partition_key="key")
    plan = cluster.scheduler.plan_join(b, p, "key")
    assert plan.shuffle_free and plan.build_name == "orders_by_key"
    base_net = cluster.net_bytes
    out, report = ClusterJoin(cluster, b, p, "key").execute()
    assert cluster.net_bytes == base_net
    assert out.tobytes() == _reference(brecs, precs).tobytes()


# -- edge cases ---------------------------------------------------------------
def test_join_empty_partitions_and_disjoint_keys():
    cluster = _cluster()
    brecs, precs = _sides(nb=40, np_=6_000, bkeys=8)
    precs["key"] += 1_000_000                 # no key overlaps the build side
    out, report = cluster_join(cluster, "j", brecs, precs, "key",
                               probe_partition_field="rid")
    assert len(out) == 0
    assert out.dtype == join_output_dtype(BUILD, PROBE, "key", "key")
    assert out.tobytes() == _reference(brecs, precs).tobytes()


def test_join_with_empty_build_side():
    cluster = _cluster()
    brecs, precs = _sides(nb=200, np_=3_000)
    out, _ = cluster_join(cluster, "j", brecs[:0], precs, "key")
    assert len(out) == 0
    out2, _ = cluster_join(cluster, "j2", brecs, precs[:0], "key")
    assert len(out2) == 0


def test_skewed_build_spill_still_byte_identical():
    """ISSUE-4 acceptance: zipf-skewed keys concentrate one node's build
    shard past its pool budget; the build spills through the eviction policy
    (no OOM) and the result is still byte-identical to the reference."""
    cluster = _cluster(node_capacity=192 << 10, page_size=1 << 13)
    brecs, precs = _sides(nb=30_000, np_=8_000, bkeys=64, pkeys=64, zipf=1.2)
    out, report = cluster_join(cluster, "j", brecs, precs, "key",
                               page_size=1 << 13)
    spills = sum(node.pool.spill.write_ops
                 for node in cluster.nodes.values() if node.alive)
    assert spills > 0                         # the build side really spilled
    assert out.tobytes() == _reference(brecs, precs).tobytes()


def test_join_through_dead_owner_replica():
    cluster = _cluster(replication_factor=1)
    brecs, precs = _sides()
    b = cluster.create_sharded_set("b", brecs, key_fn=lambda r: r["key"],
                                   partition_key="key")
    p = cluster.create_sharded_set("p", precs, key_fn=lambda r: r["key"],
                                   partition_key="key")
    cluster.kill_node(2)
    out, report = ClusterJoin(cluster, b, p, "key").execute()
    assert report.shuffle_free
    assert out.tobytes() == _reference(brecs, precs).tobytes()


def test_one_side_join_through_dead_owner_replica():
    cluster = _cluster(replication_factor=1)
    brecs, precs = _sides()
    b = cluster.create_sharded_set("b", brecs, key_fn=lambda r: r["key"],
                                   partition_key="key")
    p = cluster.create_sharded_set("p", precs, key_fn=lambda r: r["rid"],
                                   partition_key="rid")
    cluster.kill_node(1)
    out, report = ClusterJoin(cluster, b, p, "key").execute()
    assert report.plan.shuffle_sides == ("probe",)
    assert out.tobytes() == _reference(brecs, precs).tobytes()


def test_join_with_straggler_reexecution_matches_reference():
    cluster = _cluster(replication_factor=1)
    brecs, precs = _sides()
    timer = StepTimer(hosts=list(cluster.nodes), min_samples=1)
    for n in cluster.nodes:   # pre-bias the EWMA so node 0 is flagged
        for _ in range(8):
            timer.record(n, 20.0 if n == 0 else 1e-4)
    out, report = cluster_join(cluster, "j", brecs, precs, "key",
                               probe_partition_field="rid",
                               replication_factor=1, step_timer=timer)
    assert report.stragglers_redone            # work moved off the straggler
    assert all(s == 0 and b != 0 for s, b in report.stragglers_redone)
    assert out.tobytes() == _reference(brecs, precs).tobytes()


def test_both_sides_placement_uses_combined_byte_statistics():
    """place_join_reducers lands reducer r on the node with the most
    combined build+probe bytes — never worse than round-robin on the
    combined map."""
    cluster = _cluster()
    brecs, precs = _sides(nb=6_000, np_=18_000, zipf=1.3)
    b = cluster.create_sharded_set("b", brecs, key_fn=lambda r: r["rid"],
                                   partition_key="rid")
    p = cluster.create_sharded_set("p", precs, key_fn=lambda r: r["rid"],
                                   partition_key="rid")
    join = ClusterJoin(cluster, b, p, "key")
    out, report = join.execute()
    assert out.tobytes() == _reference(brecs, precs).tobytes()
    # cross-check: moved bytes never exceed what a full both-sides shuffle
    # of every map-output byte would have cost
    total = sum(report.shuffled_bytes.values())
    assert report.net_bytes <= total
