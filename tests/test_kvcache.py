"""Paged KV cache: Eq.-1-driven HBM residency (core/kvcache.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HBMExhaustedError, PagedKVCache


def _cache(hbm_pages=8, page=4):
    return PagedKVCache(num_layers=2, hbm_pages=hbm_pages, page_size=page,
                        kv_heads=2, head_dim=4)


def test_offload_and_restore_preserves_data():
    kv = _cache(hbm_pages=4)
    kv.start_sequence(0)
    kv.ensure_capacity(0, 8)   # 2 pages
    kv.advance(0, 8)
    bt = kv.block_table(0, 4)
    # write recognizable data into seq 0's pages
    kv.kv = kv.kv.at[:, bt[0]].set(1.25)
    kv.kv = kv.kv.at[:, bt[1]].set(2.5)
    # second sequence forces offload of seq 0 (cold)
    kv.start_sequence(1)
    kv.ensure_capacity(1, 12)  # 3 pages > 2 free
    kv.advance(1, 12)
    assert kv.stats["offloads"] > 0
    bt0 = kv.block_table(0, 4)   # restores offloaded pages
    assert kv.stats["fetches"] > 0
    slab0 = np.asarray(kv.kv[:, bt0[0]])
    slab1 = np.asarray(kv.kv[:, bt0[1]])
    assert np.allclose(slab0, 1.25) and np.allclose(slab1, 2.5)


def test_finished_sequences_free_pages():
    kv = _cache(hbm_pages=4)
    for s in (0, 1):
        kv.start_sequence(s)
        kv.ensure_capacity(s, 8)
        kv.advance(s, 8)
    assert kv.resident_pages() == 4
    kv.finish_sequence(0)
    assert kv.resident_pages() == 2
    kv.start_sequence(2)
    kv.ensure_capacity(2, 8)   # reuses freed pages, no offload needed
    assert kv.stats["offloads"] == 0


def test_cold_sequence_evicted_before_hot():
    kv = _cache(hbm_pages=4)
    kv.start_sequence(0)
    kv.ensure_capacity(0, 8)
    kv.advance(0, 8)
    kv.start_sequence(1)
    kv.ensure_capacity(1, 8)
    kv.advance(1, 8)
    # touch seq 1 (hot); seq 0 goes cold
    kv.block_table(1, 2)
    kv.start_sequence(2)
    kv.ensure_capacity(2, 4)   # needs 1 page -> evict from seq 0
    seq0_resident = sum(kv._pages[p].offset is not None
                        for p in kv._seqs[0].page_ids)
    seq1_resident = sum(kv._pages[p].offset is not None
                        for p in kv._seqs[1].page_ids)
    assert seq1_resident == 2
    assert seq0_resident < 2


def test_exhaustion_raises():
    kv = _cache(hbm_pages=2)
    kv.start_sequence(0)
    kv.ensure_capacity(0, 8)
    kv.advance(0, 8)
    kv.block_table(0, 2)
    # all pages belong to the single active sequence; each new page triggers
    # eviction of this sequence's own older pages (random pattern, LRU) —
    # allowed; but pinning everything via an impossible block table is not.
    kv.start_sequence(1)
    kv.ensure_capacity(1, 4)
    assert kv.stats["offloads"] > 0
