"""Paged KV cache: Eq.-1-driven HBM residency (core/kvcache.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HBMExhaustedError, PagedKVCache
from repro.kernels.paged_attention.ops import paged_attention


def _cache(hbm_pages=8, page=4):
    return PagedKVCache(num_layers=2, hbm_pages=hbm_pages, page_size=page,
                        kv_heads=2, head_dim=4)


def test_offload_and_restore_preserves_data():
    kv = _cache(hbm_pages=4)
    kv.start_sequence(0)
    kv.ensure_capacity(0, 8)   # 2 pages
    kv.advance(0, 8)
    bt = kv.block_table(0, 4)
    # write recognizable data into seq 0's pages
    kv.kv = kv.kv.at[:, bt[0]].set(1.25)
    kv.kv = kv.kv.at[:, bt[1]].set(2.5)
    # second sequence forces offload of seq 0 (cold)
    kv.start_sequence(1)
    kv.ensure_capacity(1, 12)  # 3 pages > 2 free
    kv.advance(1, 12)
    assert kv.stats["offloads"] > 0
    bt0 = kv.block_table(0, 4)   # restores offloaded pages
    assert kv.stats["fetches"] > 0
    slab0 = np.asarray(kv.kv[:, bt0[0]])
    slab1 = np.asarray(kv.kv[:, bt0[1]])
    assert np.allclose(slab0, 1.25) and np.allclose(slab1, 2.5)


def test_finished_sequences_free_pages():
    kv = _cache(hbm_pages=4)
    for s in (0, 1):
        kv.start_sequence(s)
        kv.ensure_capacity(s, 8)
        kv.advance(s, 8)
    assert kv.resident_pages() == 4
    kv.finish_sequence(0)
    assert kv.resident_pages() == 2
    kv.start_sequence(2)
    kv.ensure_capacity(2, 8)   # reuses freed pages, no offload needed
    assert kv.stats["offloads"] == 0


def test_cold_sequence_evicted_before_hot():
    kv = _cache(hbm_pages=4)
    kv.start_sequence(0)
    kv.ensure_capacity(0, 8)
    kv.advance(0, 8)
    kv.start_sequence(1)
    kv.ensure_capacity(1, 8)
    kv.advance(1, 8)
    # touch seq 1 (hot); seq 0 goes cold
    kv.block_table(1, 2)
    kv.start_sequence(2)
    kv.ensure_capacity(2, 4)   # needs 1 page -> evict from seq 0
    seq0_resident = sum(kv._pages[p].offset is not None
                        for p in kv._seqs[0].page_ids)
    seq1_resident = sum(kv._pages[p].offset is not None
                        for p in kv._seqs[1].page_ids)
    assert seq1_resident == 2
    assert seq0_resident < 2


def test_exhaustion_raises():
    kv = _cache(hbm_pages=2)
    kv.start_sequence(0)
    kv.ensure_capacity(0, 8)
    kv.advance(0, 8)
    kv.block_table(0, 2)
    # all pages belong to the single active sequence; each new page triggers
    # eviction of this sequence's own older pages (random pattern, LRU) —
    # allowed; but pinning everything via an impossible block table is not.
    kv.start_sequence(1)
    kv.ensure_capacity(1, 4)
    assert kv.stats["offloads"] > 0


# -- ragged batches through the attention kernel ------------------------------
def _seed_sequence(kv, seq, tokens, rng):
    kv.start_sequence(seq)
    kv.ensure_capacity(seq, tokens)
    kv.advance(seq, tokens)
    for k in range(kv.num_pages(seq)):
        slab = rng.standard_normal(
            (kv.num_layers, kv.page_size, 2, kv.kv_heads, kv.head_dim))
        kv.write_page(seq, k, slab.astype(np.float32))


def _attend_both(kv, seqs, layer=0):
    """Run kernel and xla reference over the live pool; they must agree."""
    rng = np.random.default_rng(7)
    max_pages = max(kv.num_pages(s) for s in seqs)
    # block_table first: it restores offloaded pages (mutates kv.kv)
    tables = np.stack([kv.block_table(s, max_pages) for s in seqs])
    lengths = np.asarray([kv.seq_length(s) for s in seqs], dtype=np.int32)
    q = rng.standard_normal(
        (len(seqs), kv.kv_heads, kv.head_dim)).astype(np.float32)
    pages = kv.kv[layer]
    ref = paged_attention(q, pages, tables, lengths, impl="xla")
    ker = paged_attention(q, pages, tables, lengths, impl="kernel",
                          interpret=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    return np.asarray(ker)


def test_attention_ragged_partial_last_pages():
    """Lengths 7/5/3 over page size 4: every sequence ends mid-page."""
    kv = _cache(hbm_pages=16)
    rng = np.random.default_rng(0)
    for seq, tokens in enumerate((7, 5, 3)):
        _seed_sequence(kv, seq, tokens, rng)
    out = _attend_both(kv, [0, 1, 2])
    assert np.isfinite(out).all()


def test_attention_length_one_sequence():
    """A single-token sequence batched with a longer one: attention over
    one key is just that key's value vector (softmax of a single logit)."""
    kv = _cache(hbm_pages=16)
    rng = np.random.default_rng(1)
    _seed_sequence(kv, 0, 1, rng)
    _seed_sequence(kv, 1, 9, rng)
    out = _attend_both(kv, [0, 1])
    slot = kv.block_table(0, 1)[0]
    v0 = np.asarray(kv.kv[0, slot, 0, 1])   # layer 0, token 0, V half
    np.testing.assert_allclose(out[0], v0, rtol=2e-5, atol=2e-5)


def test_attention_noncontiguous_pages_after_evict_restore():
    """Eviction + restore hands back arbitrary free slots, so a sequence's
    block table is no longer contiguous; the kernel must follow it and the
    restored contents must match what was written pre-eviction."""
    kv = _cache(hbm_pages=6)
    rng = np.random.default_rng(2)
    _seed_sequence(kv, 0, 12, rng)                    # 3 pages
    before = [kv.read_page(0, k).copy() for k in range(3)]
    _seed_sequence(kv, 2, 12, rng)                    # fills the pool
    _seed_sequence(kv, 1, 12, rng)                    # evicts cold seq 0
    assert kv.stats["offloads"] > 0
    kv.finish_sequence(2)                             # free slots to restore into
    out = _attend_both(kv, [0, 1])                    # restores seq 0
    assert kv.stats["fetches"] > 0
    for k in range(3):
        assert kv.read_page(0, k).tobytes() == before[k].tobytes()
    assert np.isfinite(out).all()
