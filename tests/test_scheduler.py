"""Scheduler-driven execution: locality-aware reducer placement, shuffle
elision for co-partitioned inputs, overlapped async pulls, straggler
re-execution from replica holders, and elastic remesh-degrade.

The ISSUE-2 acceptance scenarios: net_bytes == 0 for a co-partitioned hash
aggregation, and locality-aware placement strictly below the ``r % N``
baseline on a skewed shuffle.
"""
import threading
import time

import numpy as np
import pytest

from repro.core.sanitizer import tracked_lock
from repro.data.pipeline import (DistributedBatchLoader, cluster_aggregate,
                                 write_sharded_token_dataset)
from repro.runtime.cluster import (Cluster, ClusterShuffle, DeadNodeError,
                                   cluster_hash_aggregate)
from repro.runtime.scheduler import ClusterScheduler
from repro.runtime.transfer import TransferEngine, TransferError
from repro.runtime.watchdog import StepTimer

PAIR = np.dtype([("key", np.int64), ("val", np.float64)])


def _pairs(n, key_range, seed=0):
    rng = np.random.default_rng(seed)
    recs = np.zeros(n, PAIR)
    recs["key"] = rng.integers(0, key_range, n)
    recs["val"] = rng.random(n)
    return recs


def _cluster(replication_factor=1, **kw):
    kw.setdefault("node_capacity", 16 << 20)
    kw.setdefault("page_size", 1 << 16)
    return Cluster(4, replication_factor=replication_factor, **kw)


def _oracle(recs):
    uk, inv = np.unique(recs["key"], return_inverse=True)
    out = np.zeros(len(uk))
    np.add.at(out, inv, recs["val"])
    return uk, out


# -- transfer engine ----------------------------------------------------------
def test_transfer_engine_runs_jobs_and_returns_results():
    with TransferEngine(num_workers=3) as eng:
        futs = [eng.submit(lambda x: x * x, i) for i in range(10)]
        assert [f.result(timeout=10) for f in futs] == [i * i for i in range(10)]


def test_transfer_engine_orders_dependencies():
    order = []
    lock = tracked_lock("test.sched")

    def step(tag, delay=0.0):
        time.sleep(delay)
        with lock:
            order.append(tag)
        return tag

    with TransferEngine(num_workers=4) as eng:
        slow = eng.submit(step, "first", 0.05)
        dep = eng.submit(step, "second", after=[slow])
        assert dep.result(timeout=10) == "second"
        assert order == ["first", "second"]


def test_transfer_engine_propagates_dependency_failure():
    def boom():
        raise ValueError("boom")

    with TransferEngine(num_workers=2) as eng:
        bad = eng.submit(boom)
        dep = eng.submit(lambda: "ran", after=[bad])
        with pytest.raises(ValueError):
            bad.result(timeout=10)
        with pytest.raises(TransferError):
            dep.result(timeout=10)


def test_transfer_engine_drain_waits_for_everything():
    done = []
    with TransferEngine(num_workers=2) as eng:
        for i in range(6):
            eng.submit(lambda j: done.append(j) or time.sleep(0.01), i)
        eng.drain(timeout=10)
        assert len(done) == 6


# -- locality-aware reducer placement ----------------------------------------
def _skewed_shuffle(cluster, num_reducers=4, rows_heavy=4000, rows_light=50):
    """Hand-built map outputs: partition r's bytes are concentrated on node
    (r + 1) % N, so the r % N baseline is maximally wrong."""
    sh = ClusterShuffle(cluster, "skew", num_reducers, PAIR)
    rng = np.random.default_rng(0)
    # find keys that hash to each reducer partition
    probe = np.arange(200_000, dtype=np.int64)
    part = sh.partition_of_keys(probe)
    for r in range(num_reducers):
        heavy_node = (r + 1) % cluster.num_nodes
        keys = probe[part == r]
        heavy = np.zeros(rows_heavy, PAIR)
        heavy["key"] = rng.choice(keys, rows_heavy)
        heavy["val"] = rng.random(rows_heavy)
        sh.map_batch(heavy_node, heavy, key_fn=lambda p: p["key"])
        for n in range(cluster.num_nodes):
            if n == heavy_node:
                continue
            light = np.zeros(rows_light, PAIR)
            light["key"] = rng.choice(keys, rows_light)
            light["val"] = rng.random(rows_light)
            sh.map_batch(n, light, key_fn=lambda p: p["key"])
    sh.finish_maps()
    return sh


def test_locality_placement_picks_byte_heaviest_node():
    cluster = _cluster(replication_factor=0)
    sh = _skewed_shuffle(cluster)
    placement = cluster.scheduler.place_reducers("skew", 4)
    for r in range(4):
        assert placement[r] == (r + 1) % 4  # the heavy node, not r % 4
    by_node = cluster.stats.shuffle_partition_bytes("skew", 0)
    assert max(by_node, key=by_node.get) == placement[0]


def test_locality_placement_strictly_reduces_net_bytes():
    baseline = _cluster(replication_factor=0)
    sh = _skewed_shuffle(baseline)
    b0 = baseline.net_bytes
    for r in range(4):
        sh.pull(r)  # default r % N placement
    baseline_net = baseline.net_bytes - b0

    local = _cluster(replication_factor=0)
    sh2 = _skewed_shuffle(local)
    sh2.place_reducers_locally()
    predicted = local.scheduler.placement_net_bytes("skew", sh2.placement)
    b0 = local.net_bytes
    for r in range(4):
        sh2.pull(r)
    locality_net = local.net_bytes - b0

    assert locality_net < baseline_net
    assert locality_net == predicted  # the plan's cost model is exact


def test_locality_placement_never_worse_on_uniform_data():
    """On hash-uniform data the byte-heaviest node is arbitrary, but the
    chosen plan can never move more bytes than round-robin."""
    cluster = _cluster(replication_factor=0)
    recs = _pairs(20_000, 1 << 40, seed=2)
    sset = cluster.create_sharded_set("u", recs, key_fn=lambda r: r["key"])
    sh = ClusterShuffle(cluster, "u.sh", 8, PAIR)
    sh.map_sharded(sset, key_fn=lambda r: r["key"])
    sh.finish_maps()
    sched = cluster.scheduler
    base_net = sched.placement_net_bytes("u.sh", sched.baseline_placement(8))
    loc_net = sched.placement_net_bytes("u.sh", sched.place_reducers("u.sh", 8))
    assert loc_net <= base_net


# -- co-partitioned shuffle elision ------------------------------------------
def test_co_partitioned_aggregation_moves_zero_network_bytes():
    cluster = _cluster(replication_factor=0)
    recs = _pairs(30_000, 2_000, seed=3)
    sset = cluster.create_sharded_set("sales", recs,
                                      key_fn=lambda r: r["key"],
                                      partition_key="key")
    plan = cluster.scheduler.plan_aggregation(sset, "key")
    assert plan.shuffle_free
    keys, vals = cluster_hash_aggregate(cluster, sset, "key", "val")
    assert cluster.net_bytes == 0  # the ISSUE-2 acceptance criterion
    uk, oracle = _oracle(recs)
    assert np.array_equal(keys, uk)
    np.testing.assert_allclose(vals, oracle, rtol=1e-9)


def test_non_co_partitioned_aggregation_still_shuffles():
    cluster = _cluster(replication_factor=0)
    recs = _pairs(20_000, 1_000, seed=4)
    # partitioned on the set name (default), not on "key" -> no elision
    sset = cluster.create_sharded_set("src", recs, key_fn=lambda r: r["key"])
    assert not cluster.scheduler.plan_aggregation(sset, "key").shuffle_free
    keys, vals = cluster_hash_aggregate(cluster, sset, "key", "val")
    assert cluster.net_bytes > 0
    uk, oracle = _oracle(recs)
    assert np.array_equal(keys, uk)
    np.testing.assert_allclose(vals, oracle, rtol=1e-9)


def test_query_routes_to_co_partitioned_replica_set():
    """Heterogeneous replicas through the pools: the same logical records
    registered under a by-key partitioning make the aggregation shuffle-free
    even when queried through the non-co-partitioned set."""
    cluster = _cluster(replication_factor=0)
    recs = _pairs(12_000, 800, seed=18)
    src = cluster.create_sharded_set("orders", recs,
                                     key_fn=lambda r: r["val"].astype(np.int64))
    by_key = cluster.create_sharded_set("orders_by_key", recs,
                                        key_fn=lambda r: r["key"],
                                        partition_key="key")
    cluster.register_replica_set("orders", by_key)
    plan = cluster.scheduler.plan_aggregation(src, "key")
    assert plan.shuffle_free and plan.target_name == "orders_by_key"
    base_net = cluster.net_bytes
    keys, vals = cluster_hash_aggregate(cluster, src, "key", "val")
    assert cluster.net_bytes == base_net  # the replica made it shuffle-free
    uk, oracle = _oracle(recs)
    assert np.array_equal(keys, uk)
    np.testing.assert_allclose(vals, oracle, rtol=1e-9)


def test_pipeline_cluster_aggregate_is_shuffle_free_by_default():
    cluster = _cluster(replication_factor=0)
    recs = _pairs(15_000, 700, seed=5)
    keys, vals = cluster_aggregate(cluster, "s", recs, "key", "val")
    assert cluster.net_bytes == 0
    uk, oracle = _oracle(recs)
    assert np.array_equal(keys, uk)
    np.testing.assert_allclose(vals, oracle, rtol=1e-9)
    # and the shuffle path is still reachable on demand
    k2, v2 = cluster_aggregate(cluster, "s2", recs, "key", "val",
                               force_shuffle=True)
    assert cluster.net_bytes > 0
    np.testing.assert_allclose(v2, oracle, rtol=1e-9)


# -- async pulls --------------------------------------------------------------
def test_async_pull_matches_sync_results():
    recs = _pairs(40_000, 3_000, seed=6)
    results = {}
    for mode in (True, False):
        cluster = _cluster(replication_factor=0)
        sset = cluster.create_sharded_set("a", recs, key_fn=lambda r: r["key"])
        results[mode] = cluster_hash_aggregate(cluster, sset, "key", "val",
                                               num_reducers=8,
                                               async_pull=mode)
    (k_async, v_async), (k_sync, v_sync) = results[True], results[False]
    assert np.array_equal(k_async, k_sync)
    np.testing.assert_allclose(v_async, v_sync, rtol=1e-12)
    uk, oracle = _oracle(recs)
    assert np.array_equal(k_async, uk)
    np.testing.assert_allclose(v_async, oracle, rtol=1e-9)


def test_concurrent_async_pulls_are_disjoint_and_complete():
    cluster = _cluster(replication_factor=0)
    recs = _pairs(25_000, 1 << 40, seed=7)
    sset = cluster.create_sharded_set("p", recs, key_fn=lambda r: r["key"])
    sh = ClusterShuffle(cluster, "p.sh", 8, PAIR)
    sh.map_sharded(sset, key_fn=lambda r: r["key"])
    fin = sh.finish_maps_async()
    placed = cluster.transfer.submit(sh.place_reducers_locally, after=fin)
    futs = [sh.pull_async(r, after=[placed]) for r in range(8)]
    pulled = [f.result(timeout=60) for f in futs]
    allk = np.concatenate([p["key"] for p in pulled])
    assert len(allk) == len(recs)
    assert np.array_equal(np.sort(allk), np.sort(recs["key"]))
    for r, part in enumerate(pulled):
        assert (sh.partition_of_keys(part["key"]) == r).all()


# -- straggler re-execution ---------------------------------------------------
def test_straggler_map_work_reexecuted_from_replica_holder():
    cluster = _cluster(replication_factor=1)
    recs = _pairs(20_000, 1_500, seed=8)
    sset = cluster.create_sharded_set("st", recs, key_fn=lambda r: r["key"])
    sh = ClusterShuffle(cluster, "st.sh", 4, PAIR)
    sh.map_sharded(sset, key_fn=lambda r: r["key"])
    # deterministic detector input: node 2 is 10x slower than its peers
    timer = StepTimer(hosts=list(cluster.nodes), min_samples=1)
    for n in cluster.nodes:
        for _ in range(5):
            timer.record(n, 1.0 if n != 2 else 10.0)
    assert timer.stragglers() == [2]
    redone = sh.reexecute_stragglers(timer.stragglers())
    assert redone, "straggler work was not re-executed"
    straggler, backup = redone[0]
    assert straggler == 2 and backup != 2
    assert (backup, sset.replica_set_name(2, backup)) in \
        [(h, n) for h, n in sset.shards[2].replicas]
    assert 2 not in sh._services  # the slow mapper's output was discarded
    sh.finish_maps()
    pulled = [sh.pull(r) for r in range(4)]
    allk = np.concatenate([p["key"] for p in pulled])
    # nothing lost, nothing double-counted
    assert np.array_equal(np.sort(allk), np.sort(recs["key"]))


def test_map_times_attributed_to_executing_worker():
    """A dead owner's shard is mapped by its replica holder, so the step
    time must be charged to the holder — flagging the dead node would make
    re-execution a no-op (it has no work items)."""
    cluster = _cluster(replication_factor=1)
    recs = _pairs(8_000, 400, seed=19)
    sset = cluster.create_sharded_set("w", recs, key_fn=lambda r: r["key"])
    cluster.kill_node(1)
    sh = ClusterShuffle(cluster, "w.sh", 4, PAIR)
    timer = StepTimer(hosts=[])
    sh.map_sharded(sset, key_fn=lambda r: r["key"], step_timer=timer)
    assert 1 not in timer.count          # dead node never executed map work
    assert sum(timer.count.values()) == len(sset.shards)
    sh.finish_maps()
    allk = np.concatenate([sh.pull(r)["key"] for r in range(4)])
    assert np.array_equal(np.sort(allk), np.sort(recs["key"]))


def test_straggler_without_replica_keeps_its_output():
    cluster = _cluster(replication_factor=0)
    recs = _pairs(8_000, 500, seed=9)
    sset = cluster.create_sharded_set("st0", recs, key_fn=lambda r: r["key"])
    sh = ClusterShuffle(cluster, "st0.sh", 4, PAIR)
    sh.map_sharded(sset, key_fn=lambda r: r["key"])
    assert sh.reexecute_stragglers([1]) == []
    sh.finish_maps()
    allk = np.concatenate([sh.pull(r)["key"] for r in range(4)])
    assert np.array_equal(np.sort(allk), np.sort(recs["key"]))


def test_straggler_with_untracked_map_batch_output_is_not_discarded():
    """Records fed through the raw map_batch API have no work item to
    replay; discarding the straggler's service would silently lose them, so
    re-execution must refuse and keep the slow output."""
    cluster = _cluster(replication_factor=1)
    recs = _pairs(10_000, 600, seed=20)
    sset = cluster.create_sharded_set("mx", recs, key_fn=lambda r: r["key"])
    sh = ClusterShuffle(cluster, "mx.sh", 4, PAIR)
    sh.map_sharded(sset, key_fn=lambda r: r["key"])
    extra = _pairs(500, 600, seed=21)
    sh.map_batch(2, extra, key_fn=lambda p: p["key"])  # untracked records
    assert sh.reexecute_stragglers([2]) == []
    sh.finish_maps()
    allk = np.concatenate([sh.pull(r)["key"] for r in range(4)])
    assert len(allk) == len(recs) + len(extra)  # nothing lost


def test_aggregation_with_straggler_reexecution_matches_oracle():
    cluster = _cluster(replication_factor=1)
    recs = _pairs(25_000, 1_200, seed=10)
    sset = cluster.create_sharded_set("agg", recs, key_fn=lambda r: r["key"])
    timer = StepTimer(hosts=list(cluster.nodes), min_samples=1)
    for n in cluster.nodes:  # pre-bias the EWMA so node 0 is flagged
        for _ in range(8):
            timer.record(n, 20.0 if n == 0 else 1e-4)
    keys, vals = cluster_hash_aggregate(cluster, sset, "key", "val",
                                        step_timer=timer)
    uk, oracle = _oracle(recs)
    assert np.array_equal(keys, uk)
    np.testing.assert_allclose(vals, oracle, rtol=1e-9)


# -- elastic remesh degrade ---------------------------------------------------
def test_remesh_degrade_shrinks_and_preserves_data():
    cluster = _cluster(replication_factor=1)
    recs = _pairs(20_000, 1_500, seed=11)
    sset = cluster.create_sharded_set("d", recs, key_fn=lambda r: r["key"])
    cluster.kill_node(2)
    report = cluster.remesh_degrade()
    assert report.ok
    assert report.dead_nodes == [2]
    assert report.node_ids == [0, 1, 3]
    assert report.plan["mesh_shape"] == (3, 1)
    assert "d" in report.resharded
    assert sset.node_ids == [0, 1, 3]      # handle updated in place
    assert sorted(sset.shards) == [0, 1, 3]
    back = cluster.read_sharded(sset)
    assert np.array_equal(np.sort(back["key"]), np.sort(recs["key"]))
    # placement routing is consistent with the shrunk domain
    for n in [0, 1, 3]:
        shard = cluster.read_shard(sset, n)
        if len(shard):
            assert (sset.node_of_records(shard) == n).all()


def test_remesh_degrade_then_aggregate_and_create():
    cluster = _cluster(replication_factor=1)
    recs = _pairs(18_000, 900, seed=12)
    sset = cluster.create_sharded_set("d2", recs, key_fn=lambda r: r["key"],
                                      partition_key="key")
    cluster.kill_node(1)
    assert cluster.remesh_degrade().ok
    keys, vals = cluster_hash_aggregate(cluster, sset, "key", "val")
    uk, oracle = _oracle(recs)
    assert np.array_equal(keys, uk)
    np.testing.assert_allclose(vals, oracle, rtol=1e-9)
    # new sets place on the surviving membership only
    more = _pairs(4_000, 100, seed=13)
    s2 = cluster.create_sharded_set("d3", more, key_fn=lambda r: r["key"])
    assert s2.node_ids == [0, 2, 3]


def test_remesh_degrade_reports_lost_sets_without_replicas():
    cluster = _cluster(replication_factor=0)
    recs = _pairs(6_000, 300, seed=14)
    cluster.create_sharded_set("gone", recs, key_fn=lambda r: r["key"])
    cluster.kill_node(0)
    report = cluster.remesh_degrade()
    assert not report.ok
    assert report.lost == ["gone"]


def test_remesh_degrade_two_failures_with_two_replicas():
    cluster = _cluster(replication_factor=2)
    recs = _pairs(12_000, 600, seed=15)
    sset = cluster.create_sharded_set("d4", recs, key_fn=lambda r: r["key"])
    cluster.kill_node(0)
    cluster.kill_node(3)
    report = cluster.remesh_degrade()
    assert report.ok and report.node_ids == [1, 2]
    assert sset.replication_factor == 1    # clamped to the shrunk membership
    back = cluster.read_sharded(sset)
    assert np.array_equal(np.sort(back["key"]), np.sort(recs["key"]))


# -- scheduler-driven batch loader -------------------------------------------
def test_distributed_loader_prefetches_and_survives_node_loss():
    cluster = _cluster(replication_factor=1)
    rng = np.random.default_rng(16)
    toks = rng.integers(0, 1000, (512, 32), dtype=np.int32)
    sset = write_sharded_token_dataset(cluster, "tok", toks)
    cluster.kill_node(1)  # loader must read node 1's shard from its replica
    loader = DistributedBatchLoader(cluster, sset, batch_size=64, prefetch=2)
    batches = list(loader)
    assert len(batches) == 8
    seen = np.concatenate([b["tokens"] for b in batches])
    assert np.array_equal(np.sort(seen[:, 0]), np.sort(toks[:, 0]))


def test_scheduler_read_sources_prefers_primary():
    cluster = _cluster(replication_factor=1)
    recs = _pairs(4_000, 100, seed=17)
    sset = cluster.create_sharded_set("rs", recs, key_fn=lambda r: r["key"])
    sched = ClusterScheduler(cluster)
    sources = sched.read_sources(sset, 0)
    assert sources[0] == (0, sset.primary_set_name(0))
    cluster.kill_node(0)
    sources = sched.read_sources(sset, 0)
    assert sources and all(h != 0 for h, _ in sources)
