"""Parallelism presets change WHERE tensors live, never WHAT is computed:
the loss under every preset on a small sharded mesh must match the
single-device value. Runs in a subprocess so the main process keeps 1 CPU
device."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import smoke_config
from repro.launch.mesh import (batch_shardings, make_mesh, param_shardings,
                               sharding_rules)
from repro.models.model import build_model
from repro import sharding as shardlib
from jax.sharding import NamedSharding, PartitionSpec as P

cfg0 = smoke_config("deepseek-v2-lite-16b").with_(
    compute_dtype="float32", n_heads=4, kv_heads=4, d_model=64,
    n_experts=8, top_k=2, capacity_factor=8.0)
rng = np.random.default_rng(0)
B, T = 8, 16
batch = {"tokens": jnp.asarray(rng.integers(0, cfg0.vocab, (B, T)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg0.vocab, (B, T)), jnp.int32)}
model0 = build_model(cfg0)
params = model0.init(jax.random.PRNGKey(0))
ref = float(model0.loss(params, batch))

mesh = make_mesh((2, 4), ("data", "model"))
for preset in ("fsdp_tp", "dp", "fsdp_tp_sp", "serve_2d"):
    cfg = cfg0.with_(parallelism=preset)
    model = build_model(cfg)
    rules = sharding_rules(cfg, mesh)
    pspecs = param_shardings(model, cfg, mesh, rules)
    bsh = batch_shardings(batch, mesh)
    with shardlib.use_rules(rules, mesh):
        loss = float(jax.jit(model.loss, in_shardings=(pspecs, bsh))(
            jax.device_put(params, pspecs),
            jax.tree.map(lambda x, s: jax.device_put(x, s), batch, bsh)))
    assert abs(loss - ref) < 1e-4 * max(abs(ref), 1), (preset, loss, ref)
    print(f"{preset}: {loss:.6f} == {ref:.6f}")

# shard_map MoE strategy on the mesh must also match
cfg = cfg0.with_(moe_strategy="expert_parallel_shardmap")
model = build_model(cfg)
params_s = model.init(jax.random.PRNGKey(0))
ref_s = float(model.loss(params_s, batch))
rules = sharding_rules(cfg, mesh)
pspecs = param_shardings(model, cfg, mesh, rules)
bsh = batch_shardings(batch, mesh)
with shardlib.use_rules(rules, mesh):
    loss = float(jax.jit(model.loss, in_shardings=(pspecs, bsh))(
        jax.device_put(params_s, pspecs),
        jax.tree.map(lambda x, s: jax.device_put(x, s), batch, bsh)))
assert abs(loss - ref_s) < 1e-4 * max(abs(ref_s), 1), (loss, ref_s)
print(f"shardmap-moe: {loss:.6f} == {ref_s:.6f}")
print("PRESETS OK")
"""


def test_presets_preserve_loss():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "PRESETS OK" in out.stdout
