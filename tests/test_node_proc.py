"""Multi-process data plane (PR 8): per-node OS processes with the
shared-memory zero-copy page path.

Covers the proc backend against the in-process backend's contracts —
byte-identical sharded sets and shuffles, zero pickling on the page fast
path (counter-asserted), SIGKILL of a node process mid-shuffle riding the
replica re-execution path, warm page-log recovery over RPC, the revival
epoch fence, remote admission/pressure, and the resource hygiene the
backend promises: no orphan processes and no linked shm segments after
``close``.
"""
import os
import socket
import threading

import numpy as np
import pytest

from repro.core.shm_arena import (ArenaFullError, ShmArena, arena_name,
                                  gather, segment_exists)
from repro.runtime import rpc
from repro.runtime.cluster import Cluster, DeadNodeError

PAIR = np.dtype([("key", np.int64), ("val", np.float64)])


def _pairs(n, key_range, seed=0):
    rng = np.random.default_rng(seed)
    recs = np.zeros(n, PAIR)
    recs["key"] = rng.integers(0, key_range, n)
    recs["val"] = rng.random(n)
    return recs


def _sorted(recs):
    return np.sort(recs, order=["key", "val"])


def _proc(tmp_path=None, **kw):
    kw.setdefault("node_capacity", 16 << 20)
    kw.setdefault("page_size", 1 << 16)
    kw.setdefault("replication_factor", 1)
    if tmp_path is not None:
        kw.setdefault("pagelog_dir", str(tmp_path / "pagelog"))
        kw.setdefault("spill_dir", str(tmp_path / "spill"))
    return Cluster(4, backend="proc", **kw)


def _run_shuffle(cluster, recs, name, columnar=False, reducers=8):
    sset = cluster.create_sharded_set(name, recs, key_fn=lambda r: r["key"])
    sh = cluster.shuffle(f"{name}-sh", reducers, PAIR, columnar=columnar)
    sh.map_sharded(sset, key_field="key")
    sh.finish_maps()
    sh.place_reducers_locally()
    parts = [sh.pull(r) for r in range(reducers)]
    for r in range(reducers):
        sh.release_reducer(r)
    return sh, parts


# -- shm arena unit behaviour -------------------------------------------------
def test_arena_put_read_free_roundtrip():
    a = ShmArena(arena_name("t"), frame_size=64, num_frames=8,
                 create=True, owner=True)
    try:
        payload = os.urandom(200)          # spans 4 frames
        desc = a.put(payload)
        assert desc["nbytes"] == 200 and len(desc["frames"]) == 4
        assert a.read(desc).tobytes() == payload
        # a second attachment (reader) sees the same bytes
        b = ShmArena.attach(a.name, 64, 8)
        assert b.read(desc).tobytes() == payload
        b.close()
        a.free(desc)
        assert a.free_frames() == 8 and a.frames_in_use == 0
        with pytest.raises(ArenaFullError):
            a.put(os.urandom(64 * 9))
    finally:
        a.unlink()
    assert not segment_exists(a.name)


def test_arena_reader_cannot_allocate_and_gather_falls_back():
    a = ShmArena(arena_name("t"), frame_size=64, num_frames=2,
                 create=True, owner=True)
    try:
        reader = ShmArena.attach(a.name, 64, 2)
        with pytest.raises(RuntimeError):
            reader.put(b"x")
        with pytest.raises(RuntimeError):
            reader.unlink()
        reader.close()
        # gather: descriptor channel when present, raw bytes otherwise
        desc = a.put(b"abc")
        assert gather(a, desc, b"").tobytes() == b"abc"
        assert gather(a, None, b"raw-route").tobytes() == b"raw-route"
    finally:
        a.unlink()


# -- rpc framing --------------------------------------------------------------
def test_rpc_roundtrip_error_and_close():
    parent, child = socket.socketpair()
    calls = []

    def op_echo(meta, raw):
        calls.append(meta["x"])
        return {"x": meta["x"] + 1}, bytes(reversed(raw))

    def op_boom(meta, raw):
        raise ValueError("kapow")

    handlers = {"echo": op_echo, "boom": op_boom,
                "close": lambda meta, raw: {}}
    t = threading.Thread(target=rpc.serve_connection, args=(child, handlers),
                         daemon=True)
    t.start()
    conn = rpc.RpcConnection(parent, timeout_s=10)
    rep, raw = conn.call("echo", raw=b"abc", x=41)
    assert rep["x"] == 42 and raw == b"cba"
    with pytest.raises(rpc.RemoteError, match="kapow"):
        conn.call("boom")
    conn.call("close")                     # server loop replies, then exits
    t.join(5)
    assert not t.is_alive() and calls == [41]
    conn.close()


# -- sharded sets over processes ---------------------------------------------
def test_proc_sharded_set_roundtrip_and_clean_close():
    cluster = _proc()
    recs = _pairs(10_000, 1_000, seed=1)
    sset = cluster.create_sharded_set("pts", recs, key_fn=lambda r: r["key"])
    assert set(sset.shards) == {0, 1, 2, 3}
    back = cluster.read_sharded(sset)
    assert np.array_equal(_sorted(back), _sorted(recs))
    report = cluster.close()
    assert report.ok, (report.orphan_processes, report.leaked_segments)


def test_no_orphan_processes_or_segments_after_close():
    cluster = _proc()
    pids = [h.proc.pid for h in cluster.nodes.values()]
    segments = list(cluster._segments)
    assert all(os.path.exists(f"/proc/{pid}") for pid in pids)
    report = cluster.close()
    assert report.ok
    # close() joined every child: the pids are reaped, the segments unlinked
    for pid in pids:
        with pytest.raises(OSError):
            os.kill(pid, 0)
    assert not any(segment_exists(s) for s in segments)
    # idempotent: a second close reports the same clean result
    assert cluster.close().ok


# -- shuffles -----------------------------------------------------------------
def test_proc_shuffle_matches_inproc_byte_for_byte():
    recs = _pairs(20_000, 1 << 20, seed=2)
    inproc = Cluster(4, node_capacity=16 << 20, page_size=1 << 16,
                     replication_factor=1)
    sset = inproc.create_sharded_set("pts", recs, key_fn=lambda r: r["key"])
    sh = inproc.shuffle("sh", 8, PAIR)
    sh.map_sharded(sset, key_fn=lambda r: r["key"])
    sh.finish_maps()
    sh.place_reducers_locally()
    in_parts = [sh.pull(r) for r in range(8)]
    inproc.shutdown()

    proc = _proc()
    _sh, proc_parts = _run_shuffle(proc, recs, "pts")
    # both backends hash with reducer_hash: partition contents must agree
    for r in range(8):
        assert np.array_equal(_sorted(proc_parts[r]), _sorted(in_parts[r]))
    assert proc.close().ok


def test_proc_shuffle_fast_path_is_pickle_free():
    before = rpc.pickle_fallbacks()
    cluster = _proc()
    recs = _pairs(20_000, 1 << 20, seed=3)
    _sh, parts = _run_shuffle(cluster, recs, "pts")
    out = np.concatenate(parts)
    assert np.array_equal(_sorted(out), _sorted(recs))
    assert cluster.close().ok
    # every payload rode a shm descriptor or raw socket bytes; pickle is a
    # counted escape hatch that the hot path must never hit
    assert rpc.pickle_fallbacks() - before == 0


def test_proc_columnar_shuffle_is_byte_identical():
    cluster = _proc()
    recs = _pairs(20_000, 1 << 20, seed=4)
    _sh, parts = _run_shuffle(cluster, recs, "pts", columnar=True)
    out = np.concatenate(parts)
    assert np.array_equal(_sorted(out), _sorted(recs))
    assert cluster.close().ok


def test_reduce_stats_verify_partitions_in_place():
    from repro.core.replication import record_content_checksum
    cluster = _proc()
    recs = _pairs(12_000, 1 << 20, seed=5)
    sset = cluster.create_sharded_set("pts", recs, key_fn=lambda r: r["key"])
    sh = cluster.shuffle("sh", 4, PAIR)
    sh.map_sharded(sset, key_field="key")
    sh.finish_maps()
    sh.place_reducers_locally()
    total = 0
    for r in range(4):
        stats = sh.pull_remote(r)          # lands + verifies in the process
        part = sh.pull(r)                  # then materialize driver-side
        assert stats["num_records"] == len(part)
        assert stats["content_crc"] == record_content_checksum(part)
        total += len(part)
    assert total == len(recs)
    assert cluster.close().ok


# -- death and recovery -------------------------------------------------------
def test_sigkill_between_map_and_reduce_is_byte_identical():
    cluster = _proc()
    recs = _pairs(20_000, 1 << 20, seed=6)
    sset = cluster.create_sharded_set("pts", recs, key_fn=lambda r: r["key"])
    sh = cluster.shuffle("sh", 8, PAIR)
    sh.map_sharded(sset, key_field="key")
    sh.finish_maps()
    victim = 1
    victim_segments = [cluster.nodes[victim].inbox.name,
                       cluster.nodes[victim].outbox.name]
    cluster.kill_node(victim)              # SIGKILL: no goodbye, no cleanup
    assert not any(segment_exists(s) for s in victim_segments)
    sh.place_reducers_locally()
    out = np.concatenate([sh.pull(r) for r in range(8)])
    # the dead mapper's shard re-executed from its replica holder; nothing
    # was lost and nothing double-counted
    assert np.array_equal(_sorted(out), _sorted(recs))
    assert cluster.close().ok


def test_death_after_pulls_began_demands_a_rerun():
    cluster = _proc()
    recs = _pairs(12_000, 1 << 20, seed=7)
    sset = cluster.create_sharded_set("pts", recs, key_fn=lambda r: r["key"])
    sh = cluster.shuffle("sh", 4, PAIR)
    sh.map_sharded(sset, key_field="key")
    sh.finish_maps()
    sh.place_reducers_locally()
    sh.pull(0)                             # partitions started draining
    cluster.kill_node(2)
    with pytest.raises(DeadNodeError, match="re-run"):
        for r in range(1, 4):
            sh.pull(r)
    assert cluster.close().ok


def test_warm_log_recovery_over_rpc(tmp_path):
    cluster = _proc(tmp_path)
    recs = _pairs(10_000, 1_000, seed=8)
    sset = cluster.create_sharded_set("pts", recs, key_fn=lambda r: r["key"])
    cluster.kill_node(2)
    report = cluster.recover_node(2)
    assert report.ok
    assert report.warm_shards == 1 and report.warm_replicas == 1
    assert report.bytes_transferred == 0   # everything adopted from the log
    assert report.sources == {"pts:2": "pagelog"}
    back = cluster.read_sharded(sset)
    assert np.array_equal(_sorted(back), _sorted(recs))
    assert cluster.close().ok


def test_cold_recovery_copies_replica_bytes_node_to_node():
    cluster = _proc()                      # no durable tier
    recs = _pairs(10_000, 1_000, seed=9)
    sset = cluster.create_sharded_set("pts", recs, key_fn=lambda r: r["key"])
    before = cluster.net_bytes
    cluster.kill_node(3)
    report = cluster.recover_node(3)
    assert report.ok
    assert report.shards_recovered == 1 and report.warm_shards == 0
    assert report.bytes_transferred > 0
    assert cluster.net_bytes > before      # replica copy crossed nodes
    back = cluster.read_sharded(sset)
    assert np.array_equal(_sorted(back), _sorted(recs))
    assert cluster.close().ok


def test_proc_revive_fences_sets_dropped_while_dead(tmp_path):
    cluster = _proc(tmp_path)
    recs = _pairs(8_000, 500, seed=10)
    sset = cluster.create_sharded_set("pts", recs, key_fn=lambda r: r["key"])
    fenced_name = sset.shards[1].set_name
    cluster.kill_node(1)
    cluster.drop_sharded_set(sset)         # dropped everywhere else
    fenced = cluster.revive_node(1)
    # the revived node's replayed log must not resurrect the dropped set
    assert fenced_name in fenced
    rep, _ = cluster.nodes[1].call("log_sets")
    assert fenced_name not in rep["sets"]
    assert cluster.close().ok


# -- remote admission / pressure ---------------------------------------------
def test_remote_pressure_and_reservations():
    cluster = _proc(node_capacity=4 << 20)
    mem = cluster.nodes[0].memory
    assert 0.0 <= mem.pressure_score() <= 1.0
    report = cluster.pressure_report()
    assert set(report) == {0, 1, 2, 3}
    grant = mem.try_reserve(1 << 16, urgency="required", timeout=1.0)
    assert grant is not None
    grant.release()
    # saturate the staging cap, then a normal-urgency ask is refused past
    # its timeout (the first-ask liveness rule always admits on idle)
    hog = mem.try_reserve(3 << 20, urgency="required", timeout=0.5)
    assert hog is not None
    assert mem.try_reserve(3 << 20, urgency="normal", timeout=0.05) is None
    hog.release()
    assert mem.admission.admit_placement(1 << 16, deadline_s=0.2)
    assert cluster.close().ok


def test_dead_node_pressure_reads_as_zero_not_an_error():
    cluster = _proc()
    mem = cluster.nodes[2].memory
    cluster.kill_node(2)
    assert cluster.nodes[2].memory is None  # handle exposes death
    assert mem.pressure_score() == 0.0      # a raced reader degrades softly
    assert not mem.admission.admit_placement(1 << 16)
    assert cluster.close().ok
