"""End-to-end behaviour tests: train loop with checkpoint-restart, serving
loop over the paged KV manager, and a small-mesh sharded lowering
(subprocess, so the main process keeps 1 CPU device)."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs import smoke_config
from repro.launch.serve import Request, ServeLoop
from repro.launch.train import run_training


def test_train_loss_decreases():
    cfg = smoke_config("olmo-1b")
    res = run_training(cfg, steps=15, batch_size=8, seq_len=32,
                       num_sequences=32, log_every=100)
    assert res.steps == 15
    assert all(np.isfinite(l) for l in res.losses)
    assert np.mean(res.losses[-5:]) < np.mean(res.losses[:5])


def test_train_checkpoint_restart(tmp_path):
    cfg = smoke_config("qwen3-0.6b")
    with pytest.raises(RuntimeError, match="simulated failure"):
        run_training(cfg, steps=12, ckpt_dir=str(tmp_path), ckpt_every=4,
                     fail_at_step=8, log_every=100)
    res = run_training(cfg, steps=12, ckpt_dir=str(tmp_path), ckpt_every=4,
                       log_every=100)
    assert res.restored_from == 8
    assert res.steps == 12


def test_serve_loop_with_paging():
    cfg = smoke_config("glm4-9b")
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, 12, dtype=np.int32),
                    max_new_tokens=4) for i in range(6)]
    # tiny HBM page budget forces offloads while serving
    loop = ServeLoop(cfg, batch_slots=2, max_len=32, hbm_pages=3)
    out = loop.run(reqs)
    assert len(out) == 6
    assert all(len(v) == 4 for v in out.values())
    assert loop.stats["offloads"] > 0  # paging policy actually exercised


DRYRUN_SMALL = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import smoke_config
from repro.launch.mesh import (batch_shardings, make_mesh, param_shardings,
                               sharding_rules)
from repro.models.model import build_model, train_batch_specs
from repro.configs.base import ShapeConfig
from repro import sharding as shardlib
from repro.launch.hlo_analysis import analyze_hlo

cfg = smoke_config("glm4-9b").with_(n_heads=4, kv_heads=2, d_model=64)
mesh = make_mesh((2, 4), ("data", "model"))
rules = sharding_rules(cfg, mesh)
model = build_model(cfg)
shape = ShapeConfig("t", 32, 8, "train")
params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
pspecs = param_shardings(model, cfg, mesh, rules)
batch_sds = train_batch_specs(cfg, shape)
bsh = batch_shardings(batch_sds, mesh)
with shardlib.use_rules(rules, mesh):
    lowered = jax.jit(model.loss, in_shardings=(pspecs, bsh)).lower(
        params_sds, batch_sds)
    compiled = lowered.compile()
ma = compiled.memory_analysis()
assert ma is not None and ma.argument_size_in_bytes > 0
stats = analyze_hlo(compiled.as_text())
assert stats.dot_flops > 0
print("SMALL-MESH DRYRUN OK", stats.dot_flops)
"""


def test_small_mesh_sharded_lowering():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", DRYRUN_SMALL], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SMALL-MESH DRYRUN OK" in out.stdout
