"""Optimizer + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim import (adamw_init, adamw_update, compress_int8,
                         compressed_allreduce, decompress_int8,
                         make_train_step)
from repro.optim.train_state import make_train_state


def test_adamw_optimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = adamw_update(params, g, state, lr=0.1,
                                     weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_adamw_bf16_moments():
    params = {"w": jnp.ones((4, 4))}
    state = adamw_init(params, dtype="bfloat16")
    assert state.m["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((4, 4), 0.1)}
    params2, state2 = adamw_update(params, g, state)
    assert state2.v["w"].dtype == jnp.bfloat16
    assert not np.array_equal(params2["w"], params["w"])


def test_train_step_microbatching_matches_full_batch():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (8, 4))
    params = {"w": w}
    x = jax.random.normal(jax.random.fold_in(key, 1), (16, 8))
    y = jax.random.normal(jax.random.fold_in(key, 2), (16, 4))

    def loss(p, batch):
        pred = batch["x"] @ p["w"]
        return jnp.mean((pred - batch["y"]) ** 2)

    s1 = make_train_state(params)
    s2 = make_train_state(params)
    full = make_train_step(loss, lr=1e-2)
    micro = make_train_step(loss, lr=1e-2, microbatches=4)
    s1b, m1 = full(s1, {"x": x, "y": y})
    s2b, m2 = micro(s2, {"x": x, "y": y})
    # microbatched grads average per-microbatch MEANS == full-batch mean here
    np.testing.assert_allclose(m1["loss"], m2["loss"], rtol=1e-5)
    np.testing.assert_allclose(s1b.params["w"], s2b.params["w"], rtol=1e-4,
                               atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1,
                max_size=64))
def test_int8_quantization_error_bound(vals):
    g = jnp.asarray(np.array(vals, np.float32))
    q, scale = compress_int8(g)
    deq = decompress_int8(q, scale)
    amax = float(jnp.max(jnp.abs(g)))
    assert float(jnp.max(jnp.abs(deq - g))) <= amax / 127.0 + 1e-6


def test_error_feedback_converges():
    """With error feedback, the accumulated quantization bias stays bounded
    and the running mean of dequantized grads tracks the true mean."""
    rng = np.random.default_rng(0)
    true = {"w": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}
    err = None
    acc = jnp.zeros(32)
    n = 50
    for _ in range(n):
        deq, err = compressed_allreduce(true, None, err)
        acc = acc + deq["w"]
    np.testing.assert_allclose(acc / n, true["w"], atol=2e-2)
    # residual stays bounded by one quantization step
    amax = float(jnp.max(jnp.abs(true["w"]))) + float(
        jnp.max(jnp.abs(err["w"])))
    assert float(jnp.max(jnp.abs(err["w"]))) <= amax / 127.0 * 2 + 1e-5
