"""Kernel sweeps: shapes × dtypes, assert_allclose vs the pure-jnp oracles
(each Pallas kernel validated with interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.linear_scan.ops import diag_scan, gla_scan
from repro.kernels.linear_scan.ref import diag_scan_ref, gla_scan_ref
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.shuffle_dispatch.ops import combine, compute_slots, dispatch
from repro.kernels.shuffle_dispatch.ref import combine_ref, dispatch_ref

RNG = np.random.default_rng(42)


def _t(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape), dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=3e-5, atol=3e-5)


FLASH_CASES = [
    # B, H, KH, Tq, Tk, D, causal, window
    (1, 4, 2, 64, 64, 32, True, None),
    (2, 4, 4, 40, 72, 16, True, None),
    (1, 2, 1, 64, 64, 32, False, None),
    (1, 2, 2, 96, 96, 32, True, 32),
    (1, 8, 4, 128, 128, 64, True, None),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_kernel_sweep(case, dtype):
    B, H, KH, Tq, Tk, D, causal, window = case
    q, k, v = _t((B, H, Tq, D), dtype), _t((B, KH, Tk, D), dtype), \
        _t((B, KH, Tk, D), dtype)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    ker = flash_attention(q, k, v, causal=causal, window=window,
                          impl="kernel", block_q=32, block_k=32)
    xla = flash_attention(q, k, v, causal=causal, window=window, impl="xla",
                          block_k=32)
    np.testing.assert_allclose(np.asarray(ker, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(xla, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_xla_grads_match_naive():
    q, k, v = _t((1, 4, 48, 16), jnp.float32), _t((1, 2, 48, 16),
                                                  jnp.float32), \
        _t((1, 2, 48, 16), jnp.float32)

    def loss_x(q, k, v):
        return (flash_attention(q, k, v, impl="xla", block_k=16) ** 2).sum()

    def loss_r(q, k, v):
        return (attention_ref(q, k, v) ** 2).sum()

    gx = jax.grad(loss_x, (0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, (0, 1, 2))(q, k, v)
    for a, b in zip(gx, gr):
        np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)


PAGED_CASES = [
    (2, 4, 2, 32, 16, 8, 4),
    (1, 8, 8, 16, 8, 16, 3),
    (3, 4, 1, 64, 32, 8, 6),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_kernel_sweep(case, dtype):
    B, H, KH, D, P, page, maxp = case
    q = _t((B, H, D), dtype)
    kv = _t((P, page, 2, KH, D), dtype)
    bts, lens = [], []
    for b in range(B):
        n = RNG.integers(1, maxp + 1)
        pages = RNG.choice(P, size=n, replace=False)
        bt = np.full(maxp, -1, np.int32)
        bt[:n] = pages
        bts.append(bt)
        lens.append(RNG.integers((n - 1) * page + 1, n * page + 1))
    bt = jnp.asarray(np.stack(bts))
    ln = jnp.asarray(np.array(lens, np.int32))
    ref = paged_attention_ref(q, kv, bt, ln)
    ker = paged_attention(q, kv, bt, ln, impl="kernel")
    np.testing.assert_allclose(np.asarray(ker, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", [(2, 64, 16, 16), (1, 100, 8, 32),
                                  (3, 32, 32, 32)])
def test_diag_scan_sweep(case, dtype):
    B, T, D, chunk = case
    a = jnp.asarray(1 / (1 + np.exp(-RNG.normal(size=(B, T, D)))), dtype)
    b = _t((B, T, D), dtype)
    h0 = _t((B, D), dtype)
    h_ref, hT_ref = diag_scan_ref(a, b, h0)
    h_k, hT_k = diag_scan(a, b, h0, impl="kernel", chunk=chunk)
    tol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(h_k, np.float32),
                               np.asarray(h_ref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(hT_k, np.float32),
                               np.asarray(hT_ref, np.float32), **tol)


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("case", [(2, 32, 16, 16, 16), (1, 64, 32, 16, 16),
                                  (2, 48, 8, 24, 16)])
def test_gla_scan_sweep(case, dtype):
    B, T, Dk, Dv, chunk = case
    r, k = _t((B, T, Dk), dtype), _t((B, T, Dk), dtype)
    v = _t((B, T, Dv), dtype)
    w = jnp.asarray(-np.exp(RNG.normal(size=(B, T, Dk)) * 0.5), dtype)
    u = _t((B, Dk), dtype)
    o_ref, S_ref = gla_scan_ref(r, k, v, w, u)
    for impl in ("kernel", "xla_chunked"):
        o, S = gla_scan(r, k, v, w, u, impl=impl, chunk=chunk)
        np.testing.assert_allclose(o, o_ref, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(S, S_ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("case", [(64, 32, 4, 2, 32), (128, 16, 8, 1, 24),
                                  (96, 64, 16, 6, 16)])
def test_shuffle_dispatch_sweep(case):
    T, D, E, K, C = case
    x = _t((T, D), jnp.float32)
    eid = jnp.asarray(RNG.integers(0, E, size=(T, K)), jnp.int32)
    gates = jnp.asarray(RNG.random(size=(T, K)), jnp.float32)
    slot = compute_slots(eid, E, C)
    dref = dispatch_ref(x, eid, slot, E, C)
    dker = dispatch(x, eid, slot, E, C, impl="kernel")
    np.testing.assert_allclose(dker, dref, rtol=1e-5, atol=1e-5)
    y = _t((E, C, D), jnp.float32)
    cref = combine_ref(y, eid, slot, gates)
    cker = combine(y, eid, slot, gates, T, impl="kernel")
    np.testing.assert_allclose(cker, cref, rtol=1e-5, atol=1e-5)


def test_compute_slots_capacity_semantics():
    eid = jnp.asarray([[0], [0], [0], [1]], jnp.int32)
    slot = compute_slots(eid, num_experts=2, capacity=2)
    assert slot[0, 0] == 0 and slot[1, 0] == 1
    assert slot[2, 0] == 2   # over capacity -> dropped downstream
    assert slot[3, 0] == 0


def test_dispatch_combine_roundtrip_identity():
    """With K=1, no drops and gate=1, combine(dispatch(x)) == x."""
    T, D, E, C = 32, 8, 4, 32
    x = _t((T, D), jnp.float32)
    eid = jnp.asarray(RNG.integers(0, E, size=(T, 1)), jnp.int32)
    slot = compute_slots(eid, E, C)
    buf = dispatch(x, eid, slot, E, C, impl="kernel")
    back = combine(buf, eid, slot, jnp.ones((T, 1)), T, impl="kernel")
    np.testing.assert_allclose(back, x, rtol=1e-6, atol=1e-6)
