"""TLSF allocator: unit + property tests (paper §5)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.tlsf import MIN_BLOCK, TLSF


def test_alloc_free_roundtrip():
    t = TLSF(1 << 16)
    offs = [t.alloc(100) for _ in range(10)]
    assert all(o is not None for o in offs)
    assert len(set(offs)) == 10
    for o in offs:
        t.free(o)
    t.check_invariants()
    assert t.free_bytes == 1 << 16


def test_exhaustion_returns_none():
    t = TLSF(1 << 12)
    offs = []
    while (o := t.alloc(256)) is not None:
        offs.append(o)
    assert t.alloc(256) is None
    t.free(offs[0])
    assert t.alloc(256) is not None


def test_coalescing():
    t = TLSF(1 << 14)
    a = t.alloc(1 << 12)
    b = t.alloc(1 << 12)
    c = t.alloc(1 << 12)
    t.free(a)
    t.free(c)
    t.free(b)  # should coalesce into one block covering everything
    t.check_invariants()
    assert t.alloc(int(0.9 * (1 << 14))) is not None


def test_double_free_raises():
    t = TLSF(1 << 12)
    o = t.alloc(128)
    t.free(o)
    with pytest.raises(ValueError):
        t.free(o)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(64, 8192), min_size=1, max_size=64),
       st.integers(0, 2 ** 32 - 1))
def test_property_free_coalesces_to_single_maximal_block(sizes, seed):
    """Allocate a random mix, then free everything in a random order: the
    arena must collapse back to ONE free block spanning the whole capacity
    (every adjacent pair coalesced), and a full-capacity alloc must succeed."""
    cap = 1 << 17
    t = TLSF(cap)
    offs = [o for s in sizes if (o := t.alloc(s)) is not None]
    rng = np.random.default_rng(seed)
    for o in rng.permutation(np.array(offs, dtype=np.int64)).tolist():
        t.free(o)
    t.check_invariants()
    assert t.allocated_bytes == 0
    assert t.free_bytes == cap
    assert t.block_size(0) == cap          # one maximal block at offset 0
    assert t.alloc(cap) == 0               # and it is actually allocatable


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(64, 4096)),
                min_size=1, max_size=120))
def test_property_accounting_roundtrip(ops):
    """allocated_bytes + free_bytes == capacity at every step, and matches
    the sum of live block sizes exactly (arena accounting is preserved by
    arbitrary allocate/free interleavings)."""
    cap = 1 << 16
    t = TLSF(cap)
    live = {}
    for is_alloc, size in ops:
        if is_alloc or not live:
            off = t.alloc(size)
            if off is not None:
                live[off] = t.block_size(off)
                assert live[off] >= size   # rounding never shrinks a request
        else:
            off = sorted(live)[len(live) // 2]
            t.free(off)
            del live[off]
        assert t.allocated_bytes == sum(live.values())
        assert t.allocated_bytes + t.free_bytes == cap
    for off in sorted(live):
        t.free(off)
    assert t.allocated_bytes == 0 and t.free_bytes == cap


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(64, 4096)),
                min_size=1, max_size=200))
def test_property_no_overlap_and_invariants(ops):
    """Random alloc/free interleavings: live blocks never overlap; the arena
    stays fully tiled and adjacent free blocks always coalesce."""
    t = TLSF(1 << 16)
    live = {}  # offset -> size
    for is_alloc, size in ops:
        if is_alloc or not live:
            off = t.alloc(size)
            if off is not None:
                assert off not in live
                live[off] = t.block_size(off)
        else:
            off = sorted(live)[len(live) // 2]
            t.free(off)
            del live[off]
        # no overlap
        spans = sorted((o, o + s) for o, s in live.items())
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            assert a1 <= b0
        t.check_invariants()
    assert t.allocated_bytes == sum(live.values())
