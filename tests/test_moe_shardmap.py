"""Sort-based shard_map MoE vs the einsum-dispatch oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import blocks
from repro.models.moe_shardmap import _dispatch_indices, moe_shardmap_apply

RNG = np.random.default_rng(0)


def _cfg(cf=4.0):
    return smoke_config("deepseek-v2-lite-16b").with_(
        compute_dtype="float32", capacity_factor=cf)


def test_matches_einsum_moe_no_drops():
    cfg = _cfg(cf=float(4))  # capacity covers worst case -> no drops
    p, _ = blocks.moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    y_e, _ = blocks.moe_apply(p, x, cfg=cfg)
    y_s, _ = moe_shardmap_apply(p, x, cfg=cfg, mesh=None)
    np.testing.assert_allclose(y_s, y_e, rtol=1e-5, atol=1e-5)


def test_dispatch_indices_group_and_cap():
    eid = jnp.asarray([2, 0, 2, 1, 2, 0], jnp.int32)
    idx, valid = _dispatch_indices(eid, E=3, C=2)
    # expert 0 gets flat positions 1, 5; expert 1 gets 3; expert 2 capped at 2
    assert idx[0, 0] == 1 and idx[0, 1] == 5
    assert idx[1, 0] == 3 and not valid[1, 1]
    assert valid[2].all()          # first two of three kept
    assert set(np.asarray(idx[2]).tolist()) <= {0, 2, 4}


def test_grad_flows_through_shardmap_path():
    cfg = _cfg()
    p, _ = blocks.moe_init(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(RNG.normal(size=(1, 8, cfg.d_model)), jnp.float32)

    def loss(p):
        y, aux = moe_shardmap_apply(p, x, cfg=cfg, mesh=None)
        return (y ** 2).sum()

    g = jax.grad(loss)(p)
    gnorm = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
