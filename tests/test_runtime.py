"""Fault-tolerance runtime: heartbeats, stragglers, watchdog, remesh."""
import time

import pytest

from repro.runtime import (CollectiveWatchdog, HostMonitor, StepTimer,
                           plan_remesh, surviving_mesh_shape)


def test_host_monitor_detects_silence():
    t = [0.0]
    mon = HostMonitor([0, 1, 2], timeout=5.0, clock=lambda: t[0])
    failures = []
    mon.on_failure(failures.append)
    for _ in range(3):
        t[0] += 2.0
        mon.heartbeat(0)
        mon.heartbeat(1)
        # host 2 silent
    assert mon.check() == {2}
    assert failures == [{2}]
    assert mon.alive == [0, 1]
    # dead hosts stay dead even if a late heartbeat arrives
    mon.heartbeat(2)
    t[0] += 1.0
    assert mon.check() == set()
    assert 2 in mon.dead


def test_step_timer_flags_straggler():
    st = StepTimer(list(range(8)), min_samples=5)
    for _ in range(10):
        for h in range(8):
            st.record(h, 1.0 if h != 3 else 3.0)
    assert st.stragglers() == [3]


def test_step_timer_no_false_positives():
    st = StepTimer(list(range(8)), min_samples=5)
    for i in range(10):
        for h in range(8):
            st.record(h, 1.0 + 0.01 * ((h + i) % 3))
    assert st.stragglers() == []


def test_collective_watchdog_fires_and_cancels():
    fired = []
    with CollectiveWatchdog(0.05, lambda: fired.append(1)):
        time.sleep(0.15)
    assert fired == [1]
    fired2 = []
    with CollectiveWatchdog(5.0, lambda: fired2.append(1)):
        pass
    time.sleep(0.05)
    assert fired2 == []


def test_surviving_mesh_shapes():
    assert surviving_mesh_shape(256) == (16, 16)
    assert surviving_mesh_shape(240) == (15, 16)
    assert surviving_mesh_shape(15) == (1, 8)
    assert surviving_mesh_shape(1) == (1, 1)


def test_plan_remesh():
    plan = plan_remesh(64, [5], chips_per_host=4)
    assert plan["alive_hosts"] == 63
    assert plan["mesh_shape"][0] * plan["mesh_shape"][1] <= 63 * 4
    assert plan["redispatch_shards"] == [5]
